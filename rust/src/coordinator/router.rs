//! Router: the thin routing front door over the model fleet.
//!
//! The model set itself — lifecycle states, admission budgets, the shared
//! planner/tuning/thread-pool substrate, batch loops and autoscale ticks —
//! lives in [`ModelRegistry`] (`coordinator/registry.rs`). The router is
//! the stable, convenient facade the CLI, benches and tests program
//! against: register/submit/shutdown with the same signatures the
//! single-model coordinator had, now delegating to a registry that can
//! also load and unload models at runtime (the HTTP server talks to the
//! registry directly for `/load_model` and `/unload`).
//!
//! Registration comes in two flavours: [`Router::register`] with a fixed
//! [`BatchPolicy`], and [`Router::register_autoscaled`], where a
//! [`crate::coordinator::load::LoadController`] re-sizes the live
//! `max_batch` and the model's plan-cache thread ceiling from observed
//! queue depth, arrival rate and compute latency — on two triggers:
//!
//! - every `adjust_every_batches` **executed batches** (the batch loop,
//!   applied immediately: real traffic is already steering), and
//! - every [`LoadControlConfig::tick`] on a **timer** with
//!   two-consecutive-tick hysteresis ([`crate::coordinator::load::AdviceHysteresis`]).
//!   The batch-count trigger alone never fires on an idle model (no
//!   batches execute), so a burst's elevated targets would stick forever;
//!   the timer decays them once the arrival-rate EWMA's silence folding
//!   drags the advice back down.

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::engine::Engine;
use crate::coordinator::load::LoadControlConfig;
use crate::coordinator::registry::{LoadOptions, ModelRegistry};
use crate::coordinator::request::InferenceResponse;
use crate::plan::Planner;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// Thin multi-model front door delegating to a [`ModelRegistry`].
pub struct Router {
    registry: Arc<ModelRegistry>,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    /// Router over a fresh registry (and thus a fresh shared planner).
    /// Engines registered here should have been built against
    /// [`Router::registry`]'s planner to share the substrate; engines
    /// carrying their own planner still work but tune in isolation.
    pub fn new() -> Router {
        Router::with_registry(Arc::new(ModelRegistry::new(Arc::new(Planner::new()))))
    }

    /// Router over an existing registry (the CLI builds the registry
    /// first so engines and the HTTP server share its planner).
    pub fn with_registry(registry: Arc<ModelRegistry>) -> Router {
        Router { registry }
    }

    /// The registry behind the front door (lifecycle endpoints, fleet
    /// status, balancer control).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Register an engine and start its batch loop with a fixed policy.
    ///
    /// Panics if the name is already loaded — startup-time registration
    /// of a duplicate name is a configuration bug, unlike the runtime
    /// `/load_model` path which reports the conflict over HTTP.
    pub fn register(&mut self, engine: Engine, policy: BatchPolicy) {
        self.registry
            .load_engine(
                engine,
                LoadOptions {
                    policy,
                    ..LoadOptions::default()
                },
            )
            .expect("register model");
    }

    /// Register an engine whose batch ceiling and thread fan-out track
    /// observed load: every `control.adjust_every_batches` executed
    /// batches — and every `control.tick` of wall clock, so an idle
    /// model's targets decay too — the controller re-advises from the
    /// model's metrics and applies the result to the live batcher and
    /// plan cache.
    pub fn register_autoscaled(
        &mut self,
        engine: Engine,
        policy: BatchPolicy,
        control: LoadControlConfig,
    ) {
        self.registry
            .load_engine(
                engine,
                LoadOptions {
                    policy,
                    control: Some(control),
                    ..LoadOptions::default()
                },
            )
            .expect("register model");
    }

    pub fn model_names(&self) -> Vec<String> {
        self.registry.names()
    }

    pub fn engine(&self, model: &str) -> Option<Arc<Engine>> {
        self.registry.get(model).map(|h| Arc::clone(h.engine()))
    }

    /// Submit an input row; returns the response receiver.
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
    ) -> crate::Result<mpsc::Receiver<InferenceResponse>> {
        self.registry.submit(model, input)
    }

    /// Submit and block for the response (with timeout).
    pub fn infer_blocking(
        &self,
        model: &str,
        input: Vec<f32>,
        timeout: Duration,
    ) -> crate::Result<InferenceResponse> {
        self.registry.infer_blocking(model, input, timeout)
    }

    /// Stop all batch loops (draining queues first) and autoscale ticks —
    /// ticks are stopped and joined *before* any batch loop is joined
    /// (see [`ModelRegistry::shutdown`]).
    pub fn shutdown(&mut self) {
        self.registry.shutdown();
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::load::LoadControlConfig;
    use crate::model::{ModelConfig, TernaryMlp};
    use crate::plan::Planner;
    use std::sync::atomic::Ordering;

    fn router() -> Router {
        let cfg = ModelConfig::from_json(
            r#"{"name":"m1","dims":[8,16,4],"sparsity":0.5,"seed":1}"#,
        )
        .unwrap();
        let engine = Engine::new("m1", TernaryMlp::from_config(&cfg).unwrap());
        let mut r = Router::new();
        r.register(
            engine,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
        );
        r
    }

    #[test]
    fn end_to_end_single_request() {
        let r = router();
        let resp = r
            .infer_blocking("m1", vec![0.5; 8], Duration::from_secs(5))
            .unwrap();
        assert_eq!(resp.output.unwrap().len(), 4);
    }

    #[test]
    fn unknown_model_rejected() {
        let r = router();
        assert!(r.submit("nope", vec![0.0; 8]).is_err());
    }

    #[test]
    fn empty_input_rejected_before_batching() {
        let r = router();
        let err = r.submit("m1", vec![]).unwrap_err();
        assert!(err.to_string().contains("empty input"), "{err}");
        let e = r.engine("m1").unwrap();
        assert_eq!(
            e.metrics.errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let r = Arc::new(router());
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    r.infer_blocking("m1", vec![0.25; 8], Duration::from_secs(5))
                        .unwrap()
                })
            })
            .collect();
        let mut batched = 0usize;
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.output.is_ok());
            if resp.batch_size > 1 {
                batched += 1;
            }
        }
        // With 16 parallel requests and max_batch 4, at least some batches
        // should have formed (not a hard guarantee, but overwhelmingly
        // likely; tolerate zero to avoid flakes on slow machines).
        let _ = batched;
    }

    #[test]
    fn autoscaled_model_serves_and_adjusts() {
        let cfg = ModelConfig::from_json(
            r#"{"name":"a1","dims":[8,16,4],"sparsity":0.5,"seed":2}"#,
        )
        .unwrap();
        let mut r = Router::new();
        let engine = Engine::from_config(&cfg, r.registry().planner()).unwrap();
        r.register_autoscaled(
            engine,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
            },
            LoadControlConfig {
                max_batch: 16,
                max_threads: 4,
                adjust_every_batches: 1, // advise after every batch
                ..LoadControlConfig::default()
            },
        );
        let r = Arc::new(r);
        let handles: Vec<_> = (0..24)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    r.infer_blocking("a1", vec![0.1; 8], Duration::from_secs(10))
                        .unwrap()
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap().output.is_ok());
        }
        // 24 requests with a batch cap of 16 forces ≥ 2 batches, and the
        // controller advises after every one — so by the time the last
        // response (of a later batch) arrived, at least one adjustment
        // must have been recorded. Gauges are seeded at registration, so
        // only this counter proves the advise loop actually ran.
        let m = &r.engine("a1").unwrap().metrics;
        assert!(
            m.autoscale_adjustments.load(Ordering::Relaxed) >= 1,
            "load controller never re-advised"
        );
        assert!(m.max_batch_in_use.load(Ordering::Relaxed) >= 1);
        assert!(m.threads_in_use.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn autoscaled_registration_clamps_non_pow2_config_threads() {
        let cfg = ModelConfig::from_json(
            r#"{"name":"a3","dims":[8,16,4],"sparsity":0.5,"seed":5,"threads":6}"#,
        )
        .unwrap();
        let engine =
            Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
        let mut r = Router::new();
        r.register_autoscaled(
            engine,
            BatchPolicy::default(),
            LoadControlConfig {
                max_threads: 6,
                // Keep the advise tick out of this test's window so the
                // assertions observe the registration-time seed only.
                tick: Duration::from_secs(3600),
                ..LoadControlConfig::default()
            },
        );
        let e = r.engine("a3").unwrap();
        assert_eq!(
            e.plan_cache().unwrap().threads(),
            4,
            "autoscaled ceiling snaps to pow2 so warmed keys cover it"
        );
        assert_eq!(e.metrics.threads_in_use.load(Ordering::Relaxed), 4);
        // Fixed-policy registration keeps the configured value verbatim.
        let cfg2 = ModelConfig::from_json(
            r#"{"name":"a4","dims":[8,16,4],"sparsity":0.5,"seed":6,"threads":6}"#,
        )
        .unwrap();
        let engine2 =
            Engine::from_config(&cfg2, &Arc::new(Planner::new())).unwrap();
        r.register(engine2, BatchPolicy::default());
        assert_eq!(r.engine("a4").unwrap().plan_cache().unwrap().threads(), 6);
        // A pow2 config seed above the controller's ceiling is clamped to
        // it too: advice can never reach 8, so (bucket, 8) plans would be
        // unwarmed dead weight.
        let cfg3 = ModelConfig::from_json(
            r#"{"name":"a5","dims":[8,16,4],"sparsity":0.5,"seed":7,"threads":8}"#,
        )
        .unwrap();
        let engine3 =
            Engine::from_config(&cfg3, &Arc::new(Planner::new())).unwrap();
        r.register_autoscaled(
            engine3,
            BatchPolicy::default(),
            LoadControlConfig {
                max_threads: 4,
                tick: Duration::from_secs(3600),
                ..LoadControlConfig::default()
            },
        );
        assert_eq!(r.engine("a5").unwrap().plan_cache().unwrap().threads(), 4);
    }

    #[test]
    fn idle_autoscaled_model_decays_targets_via_timer_ticks() {
        let cfg = ModelConfig::from_json(
            r#"{"name":"a2","dims":[8,16,4],"sparsity":0.5,"seed":3}"#,
        )
        .unwrap();
        let engine =
            Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
        let mut r = Router::new();
        r.register_autoscaled(
            engine,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(200),
            },
            LoadControlConfig {
                max_batch: 16,
                max_threads: 4,
                // The batch-count trigger can never fire (no batches
                // execute); only the timer tick can re-advise.
                adjust_every_batches: 1_000_000,
                tick: Duration::from_millis(10),
                ..LoadControlConfig::default()
            },
        );
        // Gauges are seeded from the static policy (max_batch 8). Idle
        // advice is (min_batch = 1, threads = 1); the hysteresis applies
        // it on the second consecutive tick, so the decay must land well
        // within the (generous, anti-flake) deadline.
        let m = Arc::clone(&r.engine("a2").unwrap().metrics);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let mb = m.max_batch_in_use.load(Ordering::Relaxed);
            let th = m.threads_in_use.load(Ordering::Relaxed);
            if mb == 1 && th == 1 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "idle targets never decayed: max_batch={mb} threads={th}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            m.autoscale_adjustments.load(Ordering::Relaxed) >= 1,
            "timer tick must count as an adjustment"
        );
        r.shutdown();
        // Shutdown joined the tick thread; counters stop moving.
        let after = m.autoscale_adjustments.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(m.autoscale_adjustments.load(Ordering::Relaxed), after);
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let mut r = router();
        r.shutdown();
        assert!(r.submit("m1", vec![0.0; 8]).is_err());
    }
}
