//! Layer-3 coordinator: the serving stack.
//!
//! The paper contributes a kernel, so L3 is the inference runtime that
//! *hosts* that kernel the way the paper's motivation (quantized-LLM
//! serving) implies: requests arrive, a dynamic batcher grows the GEMM's
//! M dimension (performance-neutral for these kernels — paper Fig 8 — so
//! batching is pure throughput win), a router picks the backend (native
//! Rust kernels or the PJRT-compiled JAX/Pallas artifact), and an engine
//! executes the ternary FFN. Python never appears on this path.
//!
//! The stack is load-aware: the batcher feeds queue depth and arrival
//! rate into [`Metrics`], and an autoscaled model's batch loop
//! ([`Router::register_autoscaled`]) periodically turns those signals into
//! new `max_batch` / thread-fan-out targets via [`load::LoadController`],
//! applied to the live batcher and the model's plan cache.
//!
//! Since PR 8 the model set is dynamic: a [`registry::ModelRegistry`] owns
//! the fleet — per-model lifecycle states (`Cold` → `Warming` → `Hot` →
//! `Draining`), per-model admission queue budgets
//! ([`registry::AdmissionController`], rejecting with
//! [`SubmitError::Overloaded`]), and a demand-driven split of one fleet
//! thread budget — all over **one** shared `Planner`/`TuningTable`/thread
//! pool with per-model plan caches. [`Router`] is the thin front door;
//! models load and unload at runtime through the registry (HTTP:
//! `POST /load_model`, `POST /unload`, `GET /status` in [`server`]).
//!
//! PR 9 opens the autoregressive decode workload: a per-model
//! [`decode::DecodeScheduler`] continuously batches concurrent
//! [`crate::model::DecodeSession`]s into one M-row step through a single
//! pinned M=1-kernel [`crate::plan::MlpPlan`] (batched steps are
//! bitwise-identical to independent per-session forwards; steady state
//! allocates nothing), streaming tokens sender-per-session to the
//! chunked `POST /generate` endpoint. Schedulers drain with their model.

pub mod request;
pub mod metrics;
pub mod batcher;
pub mod decode;
pub mod engine;
pub mod load;
pub mod registry;
pub mod router;
pub mod server;
pub mod loadgen;
pub mod trace;

pub use batcher::{BatchPolicy, DynamicBatcher, SubmitError};
pub use decode::{DecodeConfig, DecodeScheduler, DecodeStream, StreamEvent, TokenEvent};
pub use engine::{Backend, Engine};
pub use load::{Advice, AdviceHysteresis, LoadControlConfig, LoadController};
pub use loadgen::{DecodeLoadGen, DecodeLoadReport, LoadGenReport, LoadGenerator};
pub use metrics::Metrics;
pub use registry::{AdmissionController, LoadOptions, ModelHandle, ModelRegistry, ModelState};
pub use request::{InferenceRequest, InferenceResponse};
pub use router::Router;
pub use server::Server;
pub use trace::{replay, OpenLoopReport, RequestTrace};
