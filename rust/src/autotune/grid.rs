//! The unroll-factor grid search (paper Figs 2–4): sweep inner (K) and
//! outer (M) unroll factors of [`UnrolledMKernel`] across K sizes, measure
//! flops/cycle, and report speedups over the baseline.

use crate::formats::Tcsc;
use crate::kernels::{BaseTcscKernel, Kernel, UnrolledMKernel};
use crate::perf::flops::CostModel;
use crate::perf::timer::CycleTimer;
use crate::tensor::Matrix;
use crate::ternary::TernaryMatrix;

/// Inner (nonzero-direction) unroll factors swept by the paper.
pub const UNROLL_K_FACTORS: [usize; 6] = [1, 2, 4, 8, 12, 16];
/// Outer (row-direction) unroll factors swept by the paper.
pub const UNROLL_M_FACTORS: [usize; 4] = [1, 2, 4, 8];

/// One grid-search measurement.
#[derive(Debug, Clone, Copy)]
pub struct GridPoint {
    pub ku: usize,
    pub mu: usize,
    pub k: usize,
    pub flops_per_cycle: f64,
    pub speedup_vs_base: f64,
}

/// Run the monomorphized (KU, MU) kernel by value — the const-generic
/// dispatch table the grid search (and benches) use.
pub fn run_unrolled_mk(
    ku: usize,
    mu: usize,
    x: &Matrix,
    w: &Tcsc,
    bias: &[f32],
    y: &mut Matrix,
) {
    macro_rules! dispatch {
        ($( ($k:literal, $m:literal) ),+ $(,)?) => {
            match (ku, mu) {
                $( ($k, $m) => UnrolledMKernel::<$k, $m>.run(x, w, bias, y), )+
                _ => panic!("unsupported unroll pair ({ku},{mu})"),
            }
        };
    }
    dispatch!(
        (1, 1), (1, 2), (1, 4), (1, 8),
        (2, 1), (2, 2), (2, 4), (2, 8),
        (4, 1), (4, 2), (4, 4), (4, 8),
        (8, 1), (8, 2), (8, 4), (8, 8),
        (12, 1), (12, 2), (12, 4), (12, 8),
        (16, 1), (16, 2), (16, 4), (16, 8),
    );
}

/// Sweep the full (KU, MU) grid for one problem shape. The paper fixes
/// s=25%, M=32, N=1024 and varies K; `reps` controls measurement cost.
pub fn unroll_grid_search(
    m: usize,
    k: usize,
    n: usize,
    sparsity: f32,
    seed: u64,
    timer: &CycleTimer,
) -> Vec<GridPoint> {
    let w = TernaryMatrix::random(k, n, sparsity, seed);
    let fmt = Tcsc::from_ternary(&w);
    let x = Matrix::random(m, k, seed + 1);
    let bias: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.1).collect();
    let flops = CostModel::new(m, k, n, sparsity).flops();
    let mut y = Matrix::zeros(m, n);

    // Baseline reference.
    let base = timer.run(|| BaseTcscKernel.run(&x, &fmt, &bias, &mut y));
    let base_fpc = base.flops_per_cycle(flops);

    let mut out = Vec::new();
    for &ku in &UNROLL_K_FACTORS {
        for &mu in &UNROLL_M_FACTORS {
            let meas = timer.run(|| run_unrolled_mk(ku, mu, &x, &fmt, &bias, &mut y));
            let fpc = meas.flops_per_cycle(flops);
            out.push(GridPoint {
                ku,
                mu,
                k,
                flops_per_cycle: fpc,
                speedup_vs_base: fpc / base_fpc,
            });
        }
    }
    out
}

/// The best point of a grid (highest flops/cycle).
pub fn best_point(points: &[GridPoint]) -> GridPoint {
    *points
        .iter()
        .max_by(|a, b| a.flops_per_cycle.partial_cmp(&b.flops_per_cycle).unwrap())
        .expect("non-empty grid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_oracle;

    #[test]
    fn dispatch_covers_all_paper_factors() {
        let w = TernaryMatrix::random(64, 16, 0.25, 5);
        let fmt = Tcsc::from_ternary(&w);
        let x = Matrix::random(8, 64, 6);
        let bias = vec![0.1f32; 16];
        let oracle = dense_oracle(&x, &w, &bias);
        for &ku in &UNROLL_K_FACTORS {
            for &mu in &UNROLL_M_FACTORS {
                let mut y = Matrix::zeros(8, 16);
                run_unrolled_mk(ku, mu, &x, &fmt, &bias, &mut y);
                assert!(y.allclose(&oracle, 1e-4), "({ku},{mu})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "unsupported unroll pair")]
    fn dispatch_rejects_unknown() {
        let w = TernaryMatrix::random(8, 4, 0.5, 1);
        let fmt = Tcsc::from_ternary(&w);
        let x = Matrix::random(1, 8, 2);
        let mut y = Matrix::zeros(1, 4);
        run_unrolled_mk(3, 5, &x, &fmt, &[0.0; 4], &mut y);
    }

    #[test]
    fn grid_search_produces_full_grid() {
        let timer = CycleTimer::new(0, 1);
        let points = unroll_grid_search(4, 64, 32, 0.25, 9, &timer);
        assert_eq!(points.len(), UNROLL_K_FACTORS.len() * UNROLL_M_FACTORS.len());
        assert!(points.iter().all(|p| p.flops_per_cycle > 0.0));
        let best = best_point(&points);
        assert!(best.speedup_vs_base > 0.0);
    }
}
