//! Tuning-table persistence: measured best kernel/parameters per problem
//! class, saved as JSON and consulted by the model builder so serving
//! picks the empirically best kernel for each layer shape — the runtime
//! counterpart of the paper's offline grid searches.
//!
//! Classes are keyed by problem shape, not by model, which is what makes
//! the table the fleet's **shared tuning substrate**: one `TuningTable`
//! lives inside the one `Planner` a
//! [`crate::coordinator::ModelRegistry`] owns, so a winner recorded while
//! serving one model is immediately consulted by every other loaded
//! model whose layers hit the same (K, sparsity, M) class.
//!
//! # Key format and fallback
//!
//! Classes are keyed `k{K}_s{S}` (M-agnostic, the PR-2 format) or
//! `k{K}_s{S}_m{M}` (M-aware, recorded when a sweep or an online race
//! observes per-batch-bucket winners diverging). [`TuningTable::lookup_m`]
//! resolves `(K, sparsity, M)` to the M-aware entry when one exists and
//! falls back to the M-agnostic `(K, sparsity)` entry otherwise, so
//! existing JSON tables keep working unchanged.
//!
//! Entries may additionally record the winning **tile geometry** (a
//! `"geometry": "p8kb4096"` field, [`TileGeometry::name`] spelling) when a
//! geometry sweep or race found a non-default geometry winning for a
//! geometry-axis kernel. The field is emitted only when present, so tables
//! written by this build stay loadable by older builds and — the other
//! direction — old name-keyed JSON loads unchanged, resolving to the
//! default geometry.

use crate::bench::harness::measure_kernel;
use crate::formats::TileGeometry;
use crate::kernels::{KernelId, KernelParams};
use crate::perf::timer::CycleTimer;
use crate::util::json::Json;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Largest M bucket: batches beyond this share one plan / tuning entry.
pub const MAX_M_BUCKET: usize = 1024;

/// Bucket a batch size: next power of two, clamped to `[1, MAX_M_BUCKET]`.
///
/// This is the **single source of truth** for M bucketing: plan-cache keys
/// and M-aware tuning classes must agree on the bucket boundaries, or a
/// cached plan could never find the entry a sweep recorded for it.
pub fn m_bucket(m: usize) -> usize {
    m.max(1).next_power_of_two().min(MAX_M_BUCKET)
}

/// Problem class key: K and sparsity always matter (paper §4); the batch
/// bucket M is optional, recorded only when per-bucket winners actually
/// diverge (M is performance-neutral for *one* kernel per paper Fig 8, but
/// the winning kernel can change with M). K is bucketed to powers of two;
/// sparsity to the paper's four levels; M to pow2 plan-cache buckets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct ShapeClass {
    pub k_bucket: u32,
    /// Sparsity in basis points (e.g. 2500 = 25%), bucketed.
    pub sparsity_bp: u32,
    /// Batch bucket for M-aware entries; `None` = M-agnostic (the PR-2
    /// key format, and the fallback every batch size resolves to).
    pub m_bucket: Option<u32>,
}

impl ShapeClass {
    /// The M-agnostic class for a shape (PR-2 semantics).
    pub fn of(k: usize, sparsity: f32) -> ShapeClass {
        ShapeClass {
            k_bucket: (k.max(1) as u32).next_power_of_two(),
            sparsity_bp: bucket_sparsity(sparsity),
            m_bucket: None,
        }
    }

    /// The M-aware class for a shape at batch size `m`.
    pub fn of_m(k: usize, sparsity: f32, m: usize) -> ShapeClass {
        ShapeClass {
            m_bucket: Some(m_bucket(m) as u32),
            ..ShapeClass::of(k, sparsity)
        }
    }

    /// This class with the M dimension dropped (the fallback key).
    pub fn m_agnostic(&self) -> ShapeClass {
        ShapeClass {
            m_bucket: None,
            ..*self
        }
    }

    fn key(&self) -> String {
        match self.m_bucket {
            Some(m) => format!("k{}_s{}_m{}", self.k_bucket, self.sparsity_bp, m),
            None => format!("k{}_s{}", self.k_bucket, self.sparsity_bp),
        }
    }

    /// Parse a table key. Values are **re-bucketed** (K snapped to a power
    /// of two, sparsity to the nearest paper level, M to a pow2 bucket):
    /// `of`/`of_m` always snap, so a hand-edited or stale key that skips
    /// the snapping could never match a lookup and would be silently dead
    /// weight. A warning is emitted when re-bucketing changed anything.
    fn parse(key: &str) -> Option<ShapeClass> {
        let rest = key.strip_prefix('k')?;
        let (k, rest) = rest.split_once("_s")?;
        let (s, m) = match rest.split_once("_m") {
            Some((s, m)) => (s, Some(m)),
            None => (rest, None),
        };
        let k: u32 = k.parse().ok()?;
        let s: u32 = s.parse().ok()?;
        let m: Option<u32> = match m {
            Some(raw) => Some(raw.parse().ok()?),
            None => None,
        };
        let parsed = ShapeClass {
            k_bucket: k,
            sparsity_bp: s,
            m_bucket: m,
        };
        let sparsity = s as f32 / 10_000.0;
        let snapped = match m {
            Some(m) => ShapeClass::of_m(k as usize, sparsity, m as usize),
            None => ShapeClass::of(k as usize, sparsity),
        };
        if snapped != parsed {
            eprintln!(
                "[tuning] warning: key '{key}' is not bucketed; re-bucketed to '{}'",
                snapped.key()
            );
        }
        Some(snapped)
    }
}

impl std::fmt::Display for ShapeClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.key())
    }
}

fn bucket_sparsity(s: f32) -> u32 {
    // Snap to the nearest paper level.
    let levels = [625u32, 1250, 2500, 5000];
    let bp = (s * 10_000.0) as i64;
    *levels
        .iter()
        .min_by_key(|&&l| (l as i64 - bp).abs())
        .unwrap()
}

/// One tuning entry: the winning kernel (typed — resolved from the
/// registry at load time, so a poisoned entry naming an unregistered
/// kernel is unrepresentable), its measured performance, and — for
/// geometry-axis kernels whose sweep/race found a non-default geometry
/// winning — the winning [`TileGeometry`]. `None` means "default
/// geometry": every pre-geometry entry resolves that way.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneEntry {
    pub kernel: KernelId,
    pub flops_per_cycle: f64,
    pub geometry: Option<TileGeometry>,
}

impl TuneEntry {
    /// Entry with the default geometry (the common case; geometry winners
    /// are attached by the sweep/race recording paths).
    pub fn new(kernel: KernelId, flops_per_cycle: f64) -> TuneEntry {
        TuneEntry {
            kernel,
            flops_per_cycle,
            geometry: None,
        }
    }
}

/// A persisted tuning table.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TuningTable {
    entries: BTreeMap<ShapeClass, TuneEntry>,
    /// Entries whose kernel name did not resolve to a [`KernelId`] at load
    /// (a table written by a build with extra kernels). They never reach
    /// lookups, but [`TuningTable::to_json`] re-emits them (kernel name,
    /// flops/cycle, raw geometry string) so a load-modify-save cycle
    /// (`autotune --save` over an existing file) does not silently destroy
    /// another build's measurements. A resolved entry recorded later for
    /// the same class shadows the unresolved one.
    unresolved: BTreeMap<ShapeClass, (String, f64, Option<String>)>,
}

impl TuningTable {
    pub fn new() -> TuningTable {
        TuningTable::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert (or replace) an entry; returns the entry it displaced.
    pub fn insert(&mut self, class: ShapeClass, entry: TuneEntry) -> Option<TuneEntry> {
        self.entries.insert(class, entry)
    }

    /// Remove one entry (sweeps retire stale M-aware splits with this).
    pub fn remove(&mut self, class: &ShapeClass) -> Option<TuneEntry> {
        self.entries.remove(class)
    }

    /// The exact M-agnostic entry for a shape, if tuned (PR-2 semantics;
    /// batch-aware callers want [`TuningTable::lookup_m`]).
    pub fn lookup(&self, k: usize, sparsity: f32) -> Option<&TuneEntry> {
        self.entries.get(&ShapeClass::of(k, sparsity))
    }

    /// Best-known entry for a shape at batch size `m`: the M-aware entry
    /// when a sweep/race recorded one for `m`'s bucket, else the
    /// M-agnostic `(K, sparsity)` entry — so PR-2-era tables keep
    /// resolving for every batch size.
    pub fn lookup_m(&self, k: usize, sparsity: f32, m: usize) -> Option<&TuneEntry> {
        self.entries
            .get(&ShapeClass::of_m(k, sparsity, m))
            .or_else(|| self.entries.get(&ShapeClass::of(k, sparsity)))
    }

    /// Kernel to use for a shape at batch size `m`: tuned winner (M-aware
    /// first, then the M-agnostic fallback) or the paper default (the
    /// registry's best-scalar capability query).
    pub fn kernel_for(&self, k: usize, sparsity: f32, m: usize) -> KernelId {
        self.lookup_m(k, sparsity, m)
            .map(|e| e.kernel)
            .unwrap_or_else(crate::kernels::best_scalar)
    }

    /// Measure the candidate set for one shape class and record the winner
    /// under the M-agnostic class (single-shape `autotune --save` flow;
    /// M-aware entries come from [`crate::autotune::sweep_model_opts`]).
    pub fn tune(
        &mut self,
        k: usize,
        sparsity: f32,
        candidates: &[KernelId],
        timer: &CycleTimer,
    ) -> TuneEntry {
        // Representative M/N: performance-neutral per the paper (Fig 8),
        // so small values keep tuning fast.
        let (m, n) = (16, 256);
        let mut best: Option<TuneEntry> = None;
        for &kernel in candidates {
            let meas = measure_kernel(
                kernel.name(),
                m,
                k,
                n,
                sparsity,
                0xA0_70_4E,
                KernelParams::default(),
                timer,
            );
            let fpc = meas.flops_per_cycle();
            if best.as_ref().map(|b| fpc > b.flops_per_cycle).unwrap_or(true) {
                best = Some(TuneEntry::new(kernel, fpc));
            }
        }
        let entry = best.expect("non-empty candidate set");
        self.insert(ShapeClass::of(k, sparsity), entry.clone());
        entry
    }

    // ---- persistence ------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let resolved = self.entries.iter().map(|(class, e)| {
            let mut fields = vec![
                ("kernel", Json::str(e.kernel.name())),
                ("flops_per_cycle", Json::num(e.flops_per_cycle)),
            ];
            // Emitted only when non-default, so tables without geometry
            // winners are byte-compatible with pre-geometry builds.
            if let Some(g) = e.geometry {
                fields.push(("geometry", Json::str(g.name())));
            }
            (class.key(), Json::obj(fields))
        });
        // Unresolved entries ride along unless a resolved entry now covers
        // their class (fresh measurements shadow foreign-build leftovers).
        let carried = self
            .unresolved
            .iter()
            .filter(|(class, _)| !self.entries.contains_key(class))
            .map(|(class, (kernel, fpc, geom))| {
                let mut fields = vec![
                    ("kernel", Json::str(kernel.clone())),
                    ("flops_per_cycle", Json::num(*fpc)),
                ];
                if let Some(g) = geom {
                    fields.push(("geometry", Json::str(g.clone())));
                }
                (class.key(), Json::obj(fields))
            });
        Json::Obj(resolved.chain(carried).collect())
    }

    /// Decode a table. Keys and kernel values stay **name-keyed on disk**
    /// (PR-2/PR-3 JSON fixtures parse unchanged); kernel names resolve to
    /// typed [`KernelId`]s here. A name the registry no longer knows (a
    /// table written by a build with extra kernels, or hand-edited) is
    /// **excluded from lookups with a warning** rather than failing the
    /// whole table — every entry that does resolve keeps working, and the
    /// unresolved entry is carried through [`TuningTable::to_json`] so a
    /// load-modify-save cycle never destroys it.
    pub fn from_json(v: &Json) -> Result<TuningTable> {
        let obj = v
            .as_obj()
            .ok_or_else(|| Error::Tuning("tuning table must be an object".into()))?;
        let mut t = TuningTable::new();
        for (key, entry) in obj {
            let class = ShapeClass::parse(key)
                .ok_or_else(|| Error::Tuning(format!("bad key '{key}'")))?;
            let name = entry
                .get("kernel")
                .and_then(|k| k.as_str())
                .ok_or_else(|| Error::Tuning(format!("entry '{key}' missing kernel")))?;
            let fpc = entry
                .get("flops_per_cycle")
                .and_then(|f| f.as_f64())
                .unwrap_or(0.0);
            let geom_raw = entry
                .get("geometry")
                .and_then(|g| g.as_str())
                .map(str::to_string);
            let kernel = match KernelId::parse(name) {
                Some(k) => k,
                None => {
                    eprintln!(
                        "[tuning] warning: key '{key}' names unknown kernel \
                         '{name}'; excluded from lookups (kept on re-save)"
                    );
                    t.unresolved.insert(class, (name.to_string(), fpc, geom_raw));
                    continue;
                }
            };
            // Absent geometry (every pre-geometry table) resolves to the
            // default; an unparseable spelling degrades the same way with
            // a warning — the kernel pick is still valid without it.
            let geometry = match &geom_raw {
                Some(raw) => {
                    let parsed = TileGeometry::parse(raw);
                    if parsed.is_none() {
                        eprintln!(
                            "[tuning] warning: key '{key}' has unknown geometry \
                             '{raw}'; resolving to the default geometry"
                        );
                    }
                    parsed
                }
                None => None,
            };
            let displaced = t.insert(
                class,
                TuneEntry {
                    kernel,
                    flops_per_cycle: fpc,
                    geometry,
                },
            );
            // Re-bucketing can make formerly-distinct keys (one snapped,
            // one not) land on the same class; the later-iterated key
            // wins (objects iterate in lexicographic key order), but
            // silently dropping a measured entry is worth a warning.
            if let Some(prev) = displaced {
                eprintln!(
                    "[tuning] warning: key '{key}' collides with an earlier \
                     entry for '{class}' after re-bucketing; replacing \
                     '{}' with '{kernel}'",
                    prev.kernel
                );
            }
        }
        Ok(t)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().encode_pretty())
            .map_err(|e| Error::io(format!("write {path}"), e))
    }

    pub fn load(path: &str) -> Result<TuningTable> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("read {path}"), e))?;
        Self::from_json(&Json::parse(&text).map_err(|e| Error::Tuning(e.to_string()))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_class_bucketing() {
        assert_eq!(ShapeClass::of(1000, 0.24).k_bucket, 1024);
        assert_eq!(ShapeClass::of(1024, 0.25).k_bucket, 1024);
        assert_eq!(ShapeClass::of(1025, 0.25).k_bucket, 2048);
        assert_eq!(ShapeClass::of(8192, 0.26).sparsity_bp, 2500);
        assert_eq!(ShapeClass::of(8192, 0.06).sparsity_bp, 625);
        assert_eq!(ShapeClass::of(1024, 0.25).m_bucket, None);
        assert_eq!(ShapeClass::of_m(1024, 0.25, 3).m_bucket, Some(4));
        assert_eq!(
            ShapeClass::of_m(1024, 0.25, 100_000).m_bucket,
            Some(MAX_M_BUCKET as u32)
        );
        assert_eq!(
            ShapeClass::of_m(1024, 0.25, 8).m_agnostic(),
            ShapeClass::of(1024, 0.25)
        );
    }

    #[test]
    fn m_buckets_are_pow2_and_capped() {
        assert_eq!(m_bucket(0), 1);
        assert_eq!(m_bucket(1), 1);
        assert_eq!(m_bucket(2), 2);
        assert_eq!(m_bucket(3), 4);
        assert_eq!(m_bucket(8), 8);
        assert_eq!(m_bucket(9), 16);
        assert_eq!(m_bucket(100_000), MAX_M_BUCKET);
    }

    #[test]
    fn key_roundtrip() {
        let c = ShapeClass::of(4096, 0.5);
        assert_eq!(c.key(), "k4096_s5000");
        assert_eq!(ShapeClass::parse(&c.key()), Some(c));
        let cm = ShapeClass::of_m(4096, 0.5, 16);
        assert_eq!(cm.key(), "k4096_s5000_m16");
        assert_eq!(ShapeClass::parse(&cm.key()), Some(cm));
        assert_eq!(ShapeClass::parse("garbage"), None);
        assert_eq!(ShapeClass::parse("k12_s25_mx"), None);
    }

    #[test]
    fn unbucketed_keys_are_rebucketed_on_parse() {
        // PR-2 bug: `k1000_s2400` round-tripped but could never match a
        // lookup, since `of()` snaps K to pow2 and sparsity to paper
        // levels — stale hand-edited JSON was silently dead weight.
        assert_eq!(
            ShapeClass::parse("k1000_s2400"),
            Some(ShapeClass::of(1000, 0.24))
        );
        assert_eq!(
            ShapeClass::parse("k1024_s2500_m3"),
            Some(ShapeClass::of_m(1024, 0.25, 3))
        );
        let mut t = TuningTable::new();
        t.insert(
            ShapeClass::parse("k1000_s2400").unwrap(),
            TuneEntry::new(KernelId::BaseTcsc, 1.0),
        );
        assert!(t.lookup(1000, 0.24).is_some(), "re-bucketed entry resolves");
    }

    #[test]
    fn lookup_m_prefers_exact_bucket_then_falls_back() {
        let mut t = TuningTable::new();
        t.insert(
            ShapeClass::of(512, 0.25),
            TuneEntry::new(KernelId::InterleavedBlockedTcsc, 2.0),
        );
        t.insert(
            ShapeClass::of_m(512, 0.25, 1),
            TuneEntry::new(KernelId::UnrolledTcscK4M4, 3.0),
        );
        // Exact bucket wins.
        assert_eq!(t.kernel_for(512, 0.25, 1), KernelId::UnrolledTcscK4M4);
        // Other buckets fall back to the M-agnostic entry.
        assert_eq!(t.kernel_for(512, 0.25, 16), KernelId::InterleavedBlockedTcsc);
        // An M-aware-only table still misses unrelated buckets...
        let mut only_m = TuningTable::new();
        only_m.insert(
            ShapeClass::of_m(256, 0.5, 8),
            TuneEntry::new(KernelId::BaseTcsc, 1.0),
        );
        assert!(only_m.lookup_m(256, 0.5, 64).is_none());
        // ...but same-bucket batch sizes share the entry (5 → bucket 8).
        assert!(only_m.lookup_m(256, 0.5, 5).is_some());
        // Untuned shapes get the paper default (the derived best-scalar
        // role, not a name literal).
        assert_eq!(t.kernel_for(2048, 0.25, 4), crate::kernels::best_scalar());
    }

    #[test]
    fn tune_records_a_winner_and_default_fallback() {
        let mut t = TuningTable::new();
        assert_eq!(t.kernel_for(2048, 0.25, 16), crate::kernels::best_scalar());
        let timer = CycleTimer::new(0, 1);
        let candidates = [KernelId::BaseTcsc, KernelId::UnrolledTcsc12];
        let entry = t.tune(512, 0.25, &candidates, &timer);
        assert!(candidates.contains(&entry.kernel));
        assert_eq!(t.kernel_for(512, 0.25, 16), entry.kernel);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = TuningTable::new();
        t.insert(
            ShapeClass::of(4096, 0.5),
            TuneEntry::new(KernelId::InterleavedBlockedTcsc, 2.5),
        );
        t.insert(
            ShapeClass::of(1024, 0.0625),
            TuneEntry::new(KernelId::UnrolledTcsc12, 1.5),
        );
        t.insert(
            ShapeClass::of_m(1024, 0.0625, 64),
            TuneEntry::new(KernelId::SimdVertical, 3.5),
        );
        let decoded = TuningTable::from_json(&t.to_json()).unwrap();
        assert_eq!(decoded, t);
    }

    #[test]
    fn geometry_field_roundtrips_and_old_json_resolves_to_default() {
        use crate::formats::TileGeometry;
        // An entry with a geometry winner round-trips through JSON.
        let mut t = TuningTable::new();
        t.insert(
            ShapeClass::of(2048, 0.25),
            TuneEntry {
                kernel: KernelId::OuterProductTileSimd,
                flops_per_cycle: 4.0,
                geometry: Some(TileGeometry::new(8, 4096)),
            },
        );
        t.insert(
            ShapeClass::of(512, 0.25),
            TuneEntry::new(KernelId::BaseTcsc, 1.0),
        );
        let json = t.to_json();
        let with_geom = json.get("k2048_s2500").unwrap();
        assert_eq!(
            with_geom.get("geometry").unwrap().as_str(),
            Some("p8kb4096"),
            "geometry is emitted in name spelling"
        );
        assert!(
            json.get("k512_s2500").unwrap().get("geometry").is_none(),
            "default-geometry entries stay byte-compatible with old builds"
        );
        assert_eq!(TuningTable::from_json(&json).unwrap(), t);
        // Old name-keyed JSON (no geometry field anywhere) loads and
        // resolves to the default geometry — the back-compat rule.
        let old = Json::parse(
            r#"{"k1024_s2500": {"kernel": "outer_product_tile", "flops_per_cycle": 2.0}}"#,
        )
        .unwrap();
        let t = TuningTable::from_json(&old).unwrap();
        let e = t.lookup(1024, 0.25).unwrap();
        assert_eq!(e.kernel, KernelId::OuterProductTile);
        assert_eq!(e.geometry, None);
        // An unparseable geometry spelling degrades to the default with
        // the kernel pick intact, instead of rejecting the table.
        let weird = Json::parse(
            r#"{"k1024_s2500": {"kernel": "outer_product_tile", "geometry": "p16kb9"}}"#,
        )
        .unwrap();
        let t = TuningTable::from_json(&weird).unwrap();
        let e = t.lookup(1024, 0.25).unwrap();
        assert_eq!(e.kernel, KernelId::OuterProductTile);
        assert_eq!(e.geometry, None);
    }

    #[test]
    fn unresolved_entries_carry_their_geometry_through_resave() {
        let json = Json::parse(
            r#"{"k1024_s2500": {"kernel": "bogus", "flops_per_cycle": 7.5,
                                "geometry": "p8kb2048"}}"#,
        )
        .unwrap();
        let t = TuningTable::from_json(&json).unwrap();
        assert!(t.is_empty(), "unknown kernel stays out of lookups");
        let back = t.to_json();
        let carried = back.get("k1024_s2500").expect("entry carried");
        assert_eq!(carried.get("kernel").unwrap().as_str(), Some("bogus"));
        assert_eq!(
            carried.get("geometry").unwrap().as_str(),
            Some("p8kb2048"),
            "foreign geometry string survives load-modify-save"
        );
    }

    #[test]
    fn colliding_rebucketed_keys_keep_one_entry_on_load() {
        // "k1000_s2500" re-buckets onto "k1024_s2500": one class survives
        // (the lexicographically later key — Json objects iterate in key
        // order) and a warning is emitted rather than a silent drop.
        let json = Json::parse(
            r#"{"k1000_s2500": {"kernel": "base_tcsc"},
                "k1024_s2500": {"kernel": "unrolled_tcsc_12"}}"#,
        )
        .unwrap();
        let t = TuningTable::from_json(&json).unwrap();
        assert_eq!(t.len(), 1, "both keys snap to the same class");
        assert_eq!(t.lookup(1024, 0.25).unwrap().kernel, KernelId::UnrolledTcsc12);
    }

    #[test]
    fn unknown_kernel_is_excluded_from_lookups_but_survives_resave() {
        // A name the registry doesn't know (table written by a newer
        // build, hand-edited) is excluded from lookups; resolvable
        // entries keep working — the whole table is not rejected.
        let json = Json::parse(
            r#"{"k1024_s2500": {"kernel": "bogus", "flops_per_cycle": 7.5},
                "k512_s2500": {"kernel": "base_tcsc"}}"#,
        )
        .unwrap();
        let mut t = TuningTable::from_json(&json).unwrap();
        assert_eq!(t.len(), 1, "unknown-kernel entry not in lookups");
        assert!(t.lookup(1024, 0.25).is_none());
        assert_eq!(t.lookup(512, 0.25).unwrap().kernel, KernelId::BaseTcsc);
        // Load-modify-save must not destroy the foreign-build entry: the
        // CLI's `--save` flow re-writes the whole file.
        let resaved = TuningTable::from_json(&t.to_json()).unwrap();
        let back = resaved.to_json();
        let carried = back.get("k1024_s2500").expect("unknown entry carried");
        assert_eq!(carried.get("kernel").unwrap().as_str(), Some("bogus"));
        assert_eq!(carried.get("flops_per_cycle").unwrap().as_f64(), Some(7.5));
        // ...unless a resolved entry now covers the class — fresh
        // measurements shadow the leftover.
        t.insert(
            ShapeClass::of(1024, 0.25),
            TuneEntry::new(KernelId::UnrolledTcsc12, 2.0),
        );
        let shadowed = t.to_json();
        assert_eq!(
            shadowed.get("k1024_s2500").unwrap().get("kernel").unwrap().as_str(),
            Some("unrolled_tcsc_12")
        );
        // A malformed key is still a hard error — that's corruption, not
        // version skew.
        let json = Json::parse(r#"{"garbage": {"kernel": "base_tcsc"}}"#).unwrap();
        assert!(TuningTable::from_json(&json).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut t = TuningTable::new();
        let timer = CycleTimer::new(0, 1);
        t.tune(256, 0.5, &[KernelId::BaseTcsc], &timer);
        t.insert(
            ShapeClass::of_m(256, 0.5, 4),
            TuneEntry::new(KernelId::UnrolledTcsc12, 2.0),
        );
        let path = std::env::temp_dir().join("stgemm_tuning_test.json");
        let path = path.to_str().unwrap();
        t.save(path).unwrap();
        assert_eq!(TuningTable::load(path).unwrap(), t);
        let _ = std::fs::remove_file(path);
    }
}
