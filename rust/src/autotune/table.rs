//! Tuning-table persistence: measured best kernel/parameters per problem
//! class, saved as JSON and consulted by the model builder so serving
//! picks the empirically best kernel for each layer shape — the runtime
//! counterpart of the paper's offline grid searches.

use crate::bench::harness::measure_kernel;
use crate::kernels::KernelParams;
use crate::perf::timer::CycleTimer;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Problem class key: K and sparsity are the parameters that matter
/// (paper §4: M and N are performance-neutral). K is bucketed to powers
/// of two; sparsity to the paper's four levels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct ShapeClass {
    pub k_bucket: u32,
    /// Sparsity in basis points (e.g. 2500 = 25%), bucketed.
    pub sparsity_bp: u32,
}

impl ShapeClass {
    pub fn of(k: usize, sparsity: f32) -> ShapeClass {
        ShapeClass {
            k_bucket: (k.max(1) as u32).next_power_of_two(),
            sparsity_bp: bucket_sparsity(sparsity),
        }
    }

    fn key(&self) -> String {
        format!("k{}_s{}", self.k_bucket, self.sparsity_bp)
    }

    fn parse(key: &str) -> Option<ShapeClass> {
        let rest = key.strip_prefix('k')?;
        let (k, s) = rest.split_once("_s")?;
        Some(ShapeClass {
            k_bucket: k.parse().ok()?,
            sparsity_bp: s.parse().ok()?,
        })
    }
}

fn bucket_sparsity(s: f32) -> u32 {
    // Snap to the nearest paper level.
    let levels = [625u32, 1250, 2500, 5000];
    let bp = (s * 10_000.0) as i64;
    *levels
        .iter()
        .min_by_key(|&&l| (l as i64 - bp).abs())
        .unwrap()
}

/// One tuning entry: the winning kernel and its measured performance.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneEntry {
    pub kernel: String,
    pub flops_per_cycle: f64,
}

/// A persisted tuning table.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TuningTable {
    entries: BTreeMap<ShapeClass, TuneEntry>,
}

impl TuningTable {
    pub fn new() -> TuningTable {
        TuningTable::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn insert(&mut self, class: ShapeClass, entry: TuneEntry) {
        self.entries.insert(class, entry);
    }

    /// Best-known kernel for a shape, if tuned.
    pub fn lookup(&self, k: usize, sparsity: f32) -> Option<&TuneEntry> {
        self.entries.get(&ShapeClass::of(k, sparsity))
    }

    /// Kernel to use for a shape: tuned winner or the paper default.
    pub fn kernel_for(&self, k: usize, sparsity: f32) -> &str {
        self.lookup(k, sparsity)
            .map(|e| e.kernel.as_str())
            .unwrap_or("interleaved_blocked_tcsc")
    }

    /// Measure the candidate set for one shape class and record the winner.
    pub fn tune(
        &mut self,
        k: usize,
        sparsity: f32,
        candidates: &[&str],
        timer: &CycleTimer,
    ) -> TuneEntry {
        // Representative M/N: performance-neutral per the paper (Fig 8),
        // so small values keep tuning fast.
        let (m, n) = (16, 256);
        let mut best: Option<TuneEntry> = None;
        for &kernel in candidates {
            let meas = measure_kernel(
                kernel,
                m,
                k,
                n,
                sparsity,
                0xA0_70_4E,
                KernelParams::default(),
                timer,
            );
            let fpc = meas.flops_per_cycle();
            if best.as_ref().map(|b| fpc > b.flops_per_cycle).unwrap_or(true) {
                best = Some(TuneEntry {
                    kernel: kernel.to_string(),
                    flops_per_cycle: fpc,
                });
            }
        }
        let entry = best.expect("non-empty candidate set");
        self.insert(ShapeClass::of(k, sparsity), entry.clone());
        entry
    }

    // ---- persistence ------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.entries
                .iter()
                .map(|(class, e)| {
                    (
                        class.key(),
                        Json::obj(vec![
                            ("kernel", Json::str(e.kernel.clone())),
                            ("flops_per_cycle", Json::num(e.flops_per_cycle)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    pub fn from_json(v: &Json) -> Result<TuningTable, String> {
        let obj = v.as_obj().ok_or("tuning table must be an object")?;
        let mut t = TuningTable::new();
        for (key, entry) in obj {
            let class = ShapeClass::parse(key).ok_or_else(|| format!("bad key '{key}'"))?;
            let kernel = entry
                .get("kernel")
                .and_then(|k| k.as_str())
                .ok_or("entry missing kernel")?
                .to_string();
            if !crate::kernels::kernel_names().contains(&kernel.as_str()) {
                return Err(format!("unknown kernel '{kernel}' in tuning table"));
            }
            let fpc = entry
                .get("flops_per_cycle")
                .and_then(|f| f.as_f64())
                .unwrap_or(0.0);
            t.insert(
                class,
                TuneEntry {
                    kernel,
                    flops_per_cycle: fpc,
                },
            );
        }
        Ok(t)
    }

    pub fn save(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_json().encode_pretty())
            .map_err(|e| format!("write {path}: {e}"))
    }

    pub fn load(path: &str) -> Result<TuningTable, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Self::from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_class_bucketing() {
        assert_eq!(ShapeClass::of(1000, 0.24).k_bucket, 1024);
        assert_eq!(ShapeClass::of(1024, 0.25).k_bucket, 1024);
        assert_eq!(ShapeClass::of(1025, 0.25).k_bucket, 2048);
        assert_eq!(ShapeClass::of(8192, 0.26).sparsity_bp, 2500);
        assert_eq!(ShapeClass::of(8192, 0.06).sparsity_bp, 625);
    }

    #[test]
    fn key_roundtrip() {
        let c = ShapeClass::of(4096, 0.5);
        assert_eq!(ShapeClass::parse(&c.key()), Some(c));
        assert_eq!(ShapeClass::parse("garbage"), None);
    }

    #[test]
    fn tune_records_a_winner_and_default_fallback() {
        let mut t = TuningTable::new();
        assert_eq!(t.kernel_for(2048, 0.25), "interleaved_blocked_tcsc");
        let timer = CycleTimer::new(0, 1);
        let entry = t.tune(512, 0.25, &["base_tcsc", "unrolled_tcsc_12"], &timer);
        assert!(["base_tcsc", "unrolled_tcsc_12"].contains(&entry.kernel.as_str()));
        assert_eq!(t.kernel_for(512, 0.25), entry.kernel);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = TuningTable::new();
        t.insert(
            ShapeClass::of(4096, 0.5),
            TuneEntry {
                kernel: "interleaved_blocked_tcsc".into(),
                flops_per_cycle: 2.5,
            },
        );
        t.insert(
            ShapeClass::of(1024, 0.0625),
            TuneEntry {
                kernel: "unrolled_tcsc_12".into(),
                flops_per_cycle: 1.5,
            },
        );
        let decoded = TuningTable::from_json(&t.to_json()).unwrap();
        assert_eq!(decoded, t);
    }

    #[test]
    fn rejects_unknown_kernel_on_load() {
        let json = Json::parse(r#"{"k1024_s2500": {"kernel": "bogus"}}"#).unwrap();
        assert!(TuningTable::from_json(&json).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut t = TuningTable::new();
        let timer = CycleTimer::new(0, 1);
        t.tune(256, 0.5, &["base_tcsc"], &timer);
        let path = std::env::temp_dir().join("stgemm_tuning_test.json");
        let path = path.to_str().unwrap();
        t.save(path).unwrap();
        assert_eq!(TuningTable::load(path).unwrap(), t);
        let _ = std::fs::remove_file(path);
    }
}
