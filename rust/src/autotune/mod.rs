//! Autotuning: the paper's unroll-factor grid search (Figs 2–4) and block
//! size selection, parameterized by a cache model so the "optimal unroll
//! shrinks as K grows" shape reproduces on any host.

pub mod grid;
pub mod cache;
pub mod sweep;
pub mod table;

pub use cache::CacheModel;
pub use grid::{run_unrolled_mk, unroll_grid_search, GridPoint, UNROLL_K_FACTORS, UNROLL_M_FACTORS};
pub use sweep::{
    admissible_candidates, decide_winners, effective_divergence, reduce_geometry, sweep_model,
    sweep_model_opts, variance_floor, SweepOptions, SweepPoint, SweepReport,
};
pub use table::{m_bucket, ShapeClass, TuneEntry, TuningTable, MAX_M_BUCKET};
