//! `stgemm autotune sweep`: fill the tuning table for **every** layer ×
//! M-bucket of a model config in one run.
//!
//! The per-shape `autotune --save` flow persists one (K, sparsity) class
//! per invocation; a multi-layer serving config needs its whole set of
//! classes covered before the planner stops falling back to heuristics
//! (or the plan cache stops racing). The sweep walks the config's layer
//! shapes and measures every candidate kernel at each batch bucket.
//!
//! Winner selection ([`decide_winners`]): every class always gets an
//! **M-agnostic** entry — the kernel with the best *mean* flops/cycle
//! across buckets, the fallback every batch size resolves to. With
//! [`SweepOptions::per_m`] (`autotune sweep --per-m`), a bucket whose own
//! winner beats that mean winner's measurement *in that bucket* by more
//! than the divergence threshold additionally gets an **M-aware**
//! `k{K}_s{S}_m{M}` entry — so a kernel that only wins at M=1 is no
//! longer silently locked in for M=64 (and vice versa).
//!
//! **Self-calibrating divergence**: the sweep's repetitions double as a
//! noise probe. Every measurement reports its coefficient of variation
//! across reps ([`crate::bench::KernelMeasurement::cycles_cv`]); a class's
//! divergence threshold is clamped to at least the *largest* CV observed
//! among its own measurements ([`variance_floor`]), so a noisy machine
//! cannot split classes on timing noise no matter how low `--divergence`
//! was set. The floor actually applied is reported in
//! [`SweepReport::variance_floor`] / [`SweepReport::effective_divergence`].
//!
//! **Geometry sweeping** ([`SweepOptions::geometry`], `autotune sweep
//! --geometry`): geometry-axis kernels (the outer-product family) are
//! measured at every [`crate::perf::geometry_candidates`] tile geometry
//! the host's caches suggest, and each kernel enters winner selection
//! with its best geometry's series ([`reduce_geometry`]). A winning
//! non-default geometry is recorded on the entry only when its gain over
//! the default layout exceeds the (noise-clamped) divergence threshold —
//! absence always means "default geometry", keeping tables one format.
//!
//! The serve-time background re-tune hook runs exactly this sweep (per-M
//! enabled) on a snapshot of the live table and installs the result.

use crate::autotune::table::{m_bucket, ShapeClass, TuneEntry, TuningTable};
use crate::bench::harness::measure_kernel;
use crate::formats::TileGeometry;
use crate::kernels::{KernelId, KernelParams};
use crate::model::ModelConfig;
use crate::perf::cpu::CpuCaps;
use crate::perf::timer::CycleTimer;

/// One (layer shape, bucket, kernel) measurement from a sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub layer: usize,
    pub k: usize,
    pub n: usize,
    pub sparsity: f32,
    pub bucket: usize,
    pub kernel: KernelId,
    pub flops_per_cycle: f64,
    /// Coefficient of variation of the measured cycles across the timer's
    /// reps (0 for a single rep) — the sweep's noise signal.
    pub cycles_cv: f64,
    /// The tile geometry this point was measured at — `Some` only when a
    /// geometry sweep varied the axis for this kernel.
    pub geometry: Option<TileGeometry>,
}

/// Winner-selection knobs for [`sweep_model_opts`].
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Record an extra winner per M bucket when the per-bucket winners
    /// diverge from the mean winner (`--per-m`). Off = PR-2 behaviour
    /// (mean collapse only).
    pub per_m: bool,
    /// Minimum relative flops/cycle gain of a bucket's own winner over
    /// the mean winner's measurement in that bucket before an M-aware
    /// entry is recorded (e.g. 0.08 = 8%). Guards against timing noise
    /// splitting every class into per-bucket entries. The sweep clamps
    /// this to at least the measured [`variance_floor`] of each class.
    /// The same (clamped) threshold gates geometry recording.
    pub divergence_threshold: f64,
    /// Measure geometry-axis kernels at every cache-suggested tile
    /// geometry (`--geometry`) and record a winning non-default geometry
    /// on the entry. Off = every kernel runs at the default geometry.
    pub geometry: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            per_m: false,
            divergence_threshold: 0.08,
            geometry: false,
        }
    }
}

/// Everything a sweep measured and decided.
#[derive(Debug, Clone, Default)]
pub struct SweepReport {
    /// Raw measurements, one per (class, bucket, kernel).
    pub points: Vec<SweepPoint>,
    /// Winners in layer order (deduplicated: layers that share a class are
    /// measured once). M-agnostic entries first per class, then any
    /// M-aware splits.
    pub winners: Vec<(ShapeClass, TuneEntry)>,
    /// Largest per-class noise floor observed (max coefficient of
    /// variation across every measurement's reps).
    pub variance_floor: f64,
    /// The divergence threshold actually applied to the noisiest class:
    /// `max(requested, variance_floor)`.
    pub effective_divergence: f64,
}

/// The noise floor of a set of measurements: the largest finite
/// coefficient of variation among them. A per-M split below this floor is
/// indistinguishable from run-to-run noise.
pub fn variance_floor(cvs: impl IntoIterator<Item = f64>) -> f64 {
    cvs.into_iter()
        .filter(|c| c.is_finite())
        .fold(0.0f64, f64::max)
}

/// Clamp a requested divergence threshold to the measured noise floor.
pub fn effective_divergence(requested: f64, floor: f64) -> f64 {
    requested.max(floor)
}

/// The subset of `candidates` whose descriptor capability requirements
/// `caps` satisfies. The sweep applies this with the host's capabilities
/// so a capability-gated kernel (e.g. the NEON outer-product tile) can
/// never be measured — or recorded as a winner — on a host that cannot
/// run it, even if a caller hands the sweep the full registry. Pure so
/// gating is testable with synthetic capability sets.
pub fn admissible_candidates(caps: &CpuCaps, candidates: &[KernelId]) -> Vec<KernelId> {
    candidates
        .iter()
        .copied()
        .filter(|id| caps.satisfies(id.descriptor().requires))
        .collect()
}

/// Geometry pre-reduction for one kernel: given its per-geometry
/// measurement series (one flops/cycle value per bucket, same bucket
/// order across series), pick the series the kernel enters winner
/// selection with. Returns `(series index, geometry to record)`.
///
/// The winner is the best *mean* series. A geometry is recorded (`Some`)
/// only when it is non-default **and** its mean beats the default
/// layout's mean by more than `threshold` — below that the default wins
/// by fiat, so tuning tables only ever carry divergent geometry winners
/// and absence keeps meaning "default". Pure so the reduction is
/// unit-testable without timing anything.
pub fn reduce_geometry(
    geoms: &[TileGeometry],
    series: &[Vec<f64>],
    threshold: f64,
) -> (usize, Option<TileGeometry>) {
    assert_eq!(geoms.len(), series.len(), "one series per geometry");
    assert!(!geoms.is_empty(), "geometry reduction needs candidates");
    let mean = |s: &[f64]| s.iter().sum::<f64>() / s.len().max(1) as f64;
    let default_idx = geoms
        .iter()
        .position(|g| *g == TileGeometry::DEFAULT)
        .unwrap_or(0);
    let best_idx = (0..series.len())
        .max_by(|&x, &y| {
            mean(&series[x])
                .partial_cmp(&mean(&series[y]))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .expect("non-empty geometry set");
    if best_idx == default_idx {
        return (default_idx, None);
    }
    let baseline = mean(&series[default_idx]).max(f64::MIN_POSITIVE);
    if mean(&series[best_idx]) / baseline <= 1.0 + threshold {
        return (default_idx, None);
    }
    (best_idx, Some(geoms[best_idx]))
}

/// Decide the tuning entries for one class from its per-(kernel, bucket)
/// measurements. `measured[i]` is a candidate kernel with one flops/cycle
/// value per entry of `buckets` (same order). Pure so the divergence
/// logic is unit-testable without timing anything.
///
/// Raw buckets are **grouped onto their pow2 M buckets first**: two raw
/// sizes that share a plan bucket share one tuning entry, so their
/// measurements are averaged — they can neither contradict each other in
/// a split nor double-weight their bucket in the mean. The M-agnostic
/// mean winner (yielded first, always) is the best mean over those
/// grouped aggregates; with `opts.per_m`, a grouped bucket whose own
/// winner beats the mean winner's aggregate there by more than the
/// threshold gets an M-aware entry too.
pub fn decide_winners(
    k: usize,
    sparsity: f32,
    buckets: &[usize],
    measured: &[(KernelId, Vec<f64>)],
    opts: &SweepOptions,
) -> Vec<(ShapeClass, TuneEntry)> {
    assert!(!measured.is_empty(), "sweep needs at least one candidate");
    // Group raw bucket indices by their snapped pow2 M bucket.
    let mut snapped: Vec<usize> = Vec::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (bi, &m) in buckets.iter().enumerate() {
        let b = m_bucket(m);
        match snapped.iter().position(|&s| s == b) {
            Some(gi) => groups[gi].push(bi),
            None => {
                snapped.push(b);
                groups.push(vec![bi]);
            }
        }
    }
    let agg = |ki: usize, group: &[usize]| {
        group.iter().map(|&bi| measured[ki].1[bi]).sum::<f64>() / group.len().max(1) as f64
    };
    let bucket_mean = |ki: usize| {
        groups.iter().map(|g| agg(ki, g)).sum::<f64>() / groups.len().max(1) as f64
    };
    let mean_idx = (0..measured.len())
        .max_by(|&x, &y| {
            bucket_mean(x)
                .partial_cmp(&bucket_mean(y))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .expect("non-empty candidate set");
    let mut winners = vec![(
        ShapeClass::of(k, sparsity),
        TuneEntry::new(measured[mean_idx].0, bucket_mean(mean_idx)),
    )];
    if !opts.per_m {
        return winners;
    }
    for (b, group) in snapped.iter().zip(&groups) {
        let best_idx = (0..measured.len())
            .max_by(|&x, &y| {
                agg(x, group)
                    .partial_cmp(&agg(y, group))
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("non-empty candidate set");
        if best_idx == mean_idx {
            continue;
        }
        let best = agg(best_idx, group);
        let baseline = agg(mean_idx, group).max(f64::MIN_POSITIVE);
        if best / baseline <= 1.0 + opts.divergence_threshold {
            continue;
        }
        winners.push((
            ShapeClass::of_m(k, sparsity, *b),
            TuneEntry::new(measured[best_idx].0, best),
        ));
    }
    winners
}

/// Measure `candidates` for every distinct (K, sparsity) class of `cfg`'s
/// layers at every bucket in `buckets`, record the class winners (see
/// [`decide_winners`]) into `table`, and return the full report.
///
/// Per-class, the divergence threshold is clamped to the class's measured
/// [`variance_floor`] before winner selection — reps double as the noise
/// probe, so `--divergence 0.01` on a noisy machine behaves like the
/// measured floor rather than splitting on noise.
///
/// Table hygiene: a swept class's **M-agnostic** entry is always
/// overwritten (fresh measurements beat stale ones). Its **M-aware**
/// splits are retired only by a per-M sweep, and only for the buckets it
/// measured — a mean-collapse sweep never evaluated per-bucket
/// divergence, so it leaves race-recorded splits in place rather than
/// silently discarding per-bucket knowledge it cannot recreate (run
/// `--per-m` to re-evaluate them). Unswept classes are untouched.
///
/// Capability hygiene: candidates are filtered through
/// [`admissible_candidates`] against the host's [`CpuCaps`] before any
/// measurement, so a gated kernel cannot be swept — let alone recorded as
/// a table winner — on a host lacking its required capabilities.
pub fn sweep_model_opts(
    cfg: &ModelConfig,
    buckets: &[usize],
    candidates: &[KernelId],
    timer: &CycleTimer,
    table: &mut TuningTable,
    opts: &SweepOptions,
) -> SweepReport {
    let candidates = admissible_candidates(&CpuCaps::host(), candidates);
    assert!(
        !candidates.is_empty(),
        "sweep needs at least one candidate runnable on this host"
    );
    let buckets: Vec<usize> = if buckets.is_empty() {
        vec![16]
    } else {
        buckets.to_vec()
    };
    let mut report = SweepReport {
        effective_divergence: opts.divergence_threshold,
        ..SweepReport::default()
    };
    let mut seen: Vec<ShapeClass> = Vec::new();
    for layer in 0..cfg.dims.len() - 1 {
        let (k, n) = (cfg.dims[layer], cfg.dims[layer + 1]);
        let class = ShapeClass::of(k, cfg.sparsity);
        if seen.contains(&class) {
            continue;
        }
        seen.push(class);
        // Per kernel: every geometry it is swept at, with one flops/cycle
        // series per geometry (bucket order matches `buckets`). Kernels
        // without the geometry axis — and every kernel when the geometry
        // sweep is off — run one series at the default geometry.
        let mut raw: Vec<(KernelId, Vec<TileGeometry>, Vec<Vec<f64>>)> =
            Vec::with_capacity(candidates.len());
        let mut class_cvs: Vec<f64> = Vec::new();
        for &kernel in &candidates {
            let sweep_geom = opts.geometry && kernel.descriptor().geometry;
            let geoms: Vec<TileGeometry> = if sweep_geom {
                crate::perf::geometry_candidates(&CpuCaps::host())
            } else {
                vec![TileGeometry::DEFAULT]
            };
            let mut series: Vec<Vec<f64>> = Vec::with_capacity(geoms.len());
            for &g in &geoms {
                let params = KernelParams {
                    geometry: if sweep_geom { Some(g) } else { None },
                    ..KernelParams::default()
                };
                let mut fpcs = Vec::with_capacity(buckets.len());
                for &m in &buckets {
                    let meas = measure_kernel(
                        kernel.name(),
                        m.max(1),
                        k,
                        n,
                        cfg.sparsity,
                        0xC0_FF_EE + layer as u64,
                        params,
                        timer,
                    );
                    let fpc = meas.flops_per_cycle();
                    class_cvs.push(meas.cycles_cv);
                    report.points.push(SweepPoint {
                        layer,
                        k,
                        n,
                        sparsity: cfg.sparsity,
                        bucket: m.max(1),
                        kernel,
                        flops_per_cycle: fpc,
                        cycles_cv: meas.cycles_cv,
                        geometry: if sweep_geom { Some(g) } else { None },
                    });
                    fpcs.push(fpc);
                }
                series.push(fpcs);
            }
            raw.push((kernel, geoms, series));
        }
        // Self-calibrating divergence: this class's measured noise floor
        // (largest CV across its reps) clamps the requested threshold, so
        // per-M splits below run-to-run noise are suppressed.
        let floor = variance_floor(class_cvs);
        report.variance_floor = report.variance_floor.max(floor);
        let class_opts = SweepOptions {
            divergence_threshold: effective_divergence(opts.divergence_threshold, floor),
            ..opts.clone()
        };
        report.effective_divergence = report
            .effective_divergence
            .max(class_opts.divergence_threshold);
        // Geometry pre-reduction: each kernel enters winner selection with
        // its best geometry's series; the geometry to record (divergent
        // non-default winners only) rides alongside.
        let mut measured: Vec<(KernelId, Vec<f64>)> = Vec::with_capacity(raw.len());
        let mut chosen: Vec<Option<TileGeometry>> = Vec::with_capacity(raw.len());
        for (kernel, geoms, series) in &raw {
            let (idx, geom) =
                reduce_geometry(geoms, series, class_opts.divergence_threshold);
            measured.push((*kernel, series[idx].clone()));
            chosen.push(geom);
        }
        // A per-M sweep re-measured every bucket it covers, so stale
        // M-aware entries for those buckets (e.g. a noisy online-race
        // winner, or a divergence split that no longer holds) must be
        // retired — `lookup_m` prefers M-aware entries, so merely
        // inserting the fresh winners could never correct them. Buckets
        // this sweep did not measure keep their entries.
        if opts.per_m {
            for &m in &buckets {
                table.remove(&ShapeClass::of_m(k, cfg.sparsity, m));
            }
        }
        for (class, mut entry) in
            decide_winners(k, cfg.sparsity, &buckets, &measured, &class_opts)
        {
            // Attach the winner kernel's reduced geometry (candidates are
            // unique, so the position lookup is unambiguous).
            let ki = measured
                .iter()
                .position(|(kid, _)| *kid == entry.kernel)
                .expect("winner kernel came from the measured set");
            entry.geometry = chosen[ki];
            table.insert(class, entry.clone());
            report.winners.push((class, entry));
        }
    }
    report
}

/// [`sweep_model_opts`] with default options: M-agnostic mean winners
/// only, exactly the PR-2 behaviour.
pub fn sweep_model(
    cfg: &ModelConfig,
    buckets: &[usize],
    candidates: &[KernelId],
    timer: &CycleTimer,
    table: &mut TuningTable,
) -> SweepReport {
    sweep_model_opts(cfg, buckets, candidates, timer, table, &SweepOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Arbitrary distinct candidates for the pure decide_winners tests.
    const A: KernelId = KernelId::BaseTcsc;
    const B: KernelId = KernelId::UnrolledTcsc12;

    fn cfg() -> ModelConfig {
        ModelConfig::from_json(
            r#"{"name":"s","dims":[32,64,16],"sparsity":0.25,"seed":1,
                "batch_buckets":[1,4]}"#,
        )
        .unwrap()
    }

    fn entry_for(winners: &[(ShapeClass, TuneEntry)], class: ShapeClass) -> Option<&TuneEntry> {
        winners.iter().find(|(c, _)| *c == class).map(|(_, e)| e)
    }

    #[test]
    fn sweep_covers_every_layer_class() {
        let c = cfg();
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        let report = sweep_model(&c, &c.batch_buckets, &[A, B], &timer, &mut table);
        // Two distinct classes (K=32 and K=64 at 25%), each covered.
        assert_eq!(report.winners.len(), 2);
        for i in 0..c.dims.len() - 1 {
            assert!(
                table.lookup(c.dims[i], c.sparsity).is_some(),
                "layer {i} class untuned after sweep"
            );
        }
        // classes × kernels × buckets raw points.
        assert_eq!(report.points.len(), 2 * 2 * 2);
        assert!(report.points.iter().all(|p| p.flops_per_cycle > 0.0));
    }

    #[test]
    fn shared_classes_are_measured_once() {
        let c = ModelConfig::from_json(
            r#"{"name":"s","dims":[64,64,64],"sparsity":0.25,"seed":1}"#,
        )
        .unwrap();
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        let report = sweep_model(&c, &[1], &[A], &timer, &mut table);
        assert_eq!(report.winners.len(), 1, "one class, measured once");
        assert_eq!(table.len(), 1);
    }

    #[test]
    fn empty_buckets_fall_back_to_default() {
        let c = cfg();
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        let report = sweep_model(&c, &[], &[A], &timer, &mut table);
        assert_eq!(report.points.len(), 2, "one default bucket per class");
        assert!(report.points.iter().all(|p| p.bucket == 16));
    }

    #[test]
    fn capability_gated_candidates_are_filtered() {
        use crate::kernels::{available_ids, kernel_ids, KernelFamily};
        // A scalar-only capability set loses exactly the gated kernels —
        // and agrees with the registry's own availability query.
        let scalar = admissible_candidates(&CpuCaps::scalar_only(), kernel_ids());
        assert!(scalar.iter().all(|id| id.descriptor().requires.is_empty()));
        assert!(
            scalar
                .iter()
                .any(|id| id.descriptor().family == KernelFamily::OuterProduct),
            "portable tile emulation must survive scalar filtering"
        );
        assert!(!scalar.contains(&KernelId::OuterProductTileSimd));
        assert_eq!(scalar, available_ids(&CpuCaps::scalar_only()));
        // An apple-like capability set keeps the full registry.
        let apple = admissible_candidates(&CpuCaps::apple_like(), kernel_ids());
        assert_eq!(apple, kernel_ids().to_vec());
    }

    #[test]
    fn capability_gated_sweep_never_measures_unrunnable_kernels() {
        use crate::kernels::kernel_ids;
        let c = cfg();
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        // Hand the sweep the *full* registry: the host filter must drop
        // anything this machine cannot run before measurement, so every
        // point and every recorded winner is runnable here.
        let report = sweep_model(&c, &[1], kernel_ids(), &timer, &mut table);
        let caps = CpuCaps::host();
        for p in &report.points {
            assert!(
                caps.satisfies(p.kernel.descriptor().requires),
                "swept a kernel the host cannot run: {}",
                p.kernel
            );
        }
        for (_, entry) in &report.winners {
            assert!(caps.satisfies(entry.kernel.descriptor().requires));
        }
        assert!(!report.points.is_empty());
    }

    #[test]
    fn variance_floor_is_max_finite_cv() {
        assert_eq!(variance_floor([]), 0.0);
        assert_eq!(variance_floor([0.02, 0.11, 0.05]), 0.11);
        assert_eq!(variance_floor([0.02, f64::NAN, f64::INFINITY]), 0.02);
        assert_eq!(effective_divergence(0.08, 0.03), 0.08);
        assert_eq!(effective_divergence(0.08, 0.15), 0.15, "noise clamps up");
    }

    #[test]
    fn sweep_reports_noise_floor_and_clamped_divergence() {
        let c = cfg();
        // Multiple reps so a CV can actually be measured.
        let timer = CycleTimer::new(0, 3);
        let mut table = TuningTable::new();
        let opts = SweepOptions {
            per_m: true,
            divergence_threshold: 0.0, // degenerate request: split on anything
            ..Default::default()
        };
        let report = sweep_model_opts(&c, &c.batch_buckets, &[A, B], &timer, &mut table, &opts);
        assert!(report.variance_floor >= 0.0);
        assert!(
            report.effective_divergence >= report.variance_floor,
            "applied threshold is never below the measured floor"
        );
        assert!(report.points.iter().all(|p| p.cycles_cv >= 0.0));
        // Single-rep timers have no spread to measure: floor stays 0 and
        // the requested threshold passes through unclamped.
        let timer1 = CycleTimer::new(0, 1);
        let report1 =
            sweep_model_opts(&c, &c.batch_buckets, &[A], &timer1, &mut table, &opts);
        assert_eq!(report1.variance_floor, 0.0);
        assert_eq!(report1.effective_divergence, 0.0);
    }

    #[test]
    fn decide_winners_mean_collapse_without_per_m() {
        // Kernel A wins at M=1, B wins (bigger) at M=16: B has the better
        // mean, and without per_m that is the only entry recorded.
        let measured = vec![(A, vec![3.0, 1.0]), (B, vec![2.0, 4.0])];
        let w = decide_winners(64, 0.25, &[1, 16], &measured, &SweepOptions::default());
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].0, ShapeClass::of(64, 0.25));
        assert_eq!(w[0].1.kernel, B);
        assert!((w[0].1.flops_per_cycle - 3.0).abs() < 1e-9, "mean of 2 and 4");
    }

    #[test]
    fn decide_winners_splits_diverging_buckets() {
        let measured = vec![(A, vec![3.0, 1.0]), (B, vec![2.0, 4.0])];
        let opts = SweepOptions {
            per_m: true,
            divergence_threshold: 0.10,
            ..Default::default()
        };
        let w = decide_winners(64, 0.25, &[1, 16], &measured, &opts);
        // Mean winner B, plus an M-aware split for bucket 1 where A's 3.0
        // beats B's 2.0 by 50% > 10%.
        assert_eq!(w.len(), 2);
        assert_eq!(entry_for(&w, ShapeClass::of(64, 0.25)).unwrap().kernel, B);
        let split = entry_for(&w, ShapeClass::of_m(64, 0.25, 1)).unwrap();
        assert_eq!(split.kernel, A);
        assert!((split.flops_per_cycle - 3.0).abs() < 1e-9);
        // No entry for bucket 16: B wins it outright.
        assert!(entry_for(&w, ShapeClass::of_m(64, 0.25, 16)).is_none());
    }

    #[test]
    fn decide_winners_threshold_suppresses_noise_splits() {
        // A beats B at M=1 by only 4% — below an 8% threshold, so the
        // divergence is treated as noise and collapsed into the mean.
        let measured = vec![(A, vec![2.08, 1.0]), (B, vec![2.0, 4.0])];
        let opts = SweepOptions {
            per_m: true,
            divergence_threshold: 0.08,
            ..Default::default()
        };
        let w = decide_winners(64, 0.25, &[1, 16], &measured, &opts);
        assert_eq!(w.len(), 1, "4% gain must not split the class");
        // Raise the gain past the threshold and the split appears.
        let measured = vec![(A, vec![2.4, 1.0]), (B, vec![2.0, 4.0])];
        let w = decide_winners(64, 0.25, &[1, 16], &measured, &opts);
        assert_eq!(w.len(), 2, "20% gain splits the class");
    }

    #[test]
    fn decide_winners_groups_same_pow2_bucket_before_selection() {
        // Raw buckets 3 and 4 both snap to M bucket 4: their measurements
        // are averaged before winner selection, yielding one entry whose
        // flops/cycle is the group aggregate.
        let measured = vec![(A, vec![3.0, 3.5, 1.0]), (B, vec![2.0, 2.0, 4.0])];
        let opts = SweepOptions {
            per_m: true,
            divergence_threshold: 0.10,
            ..Default::default()
        };
        let w = decide_winners(64, 0.25, &[3, 4, 16], &measured, &opts);
        let split = entry_for(&w, ShapeClass::of_m(64, 0.25, 4)).unwrap();
        assert_eq!(split.kernel, A);
        assert!((split.flops_per_cycle - 3.25).abs() < 1e-9, "mean of 3.0, 3.5");
        assert_eq!(w.len(), 2, "one agnostic + one grouped M-aware entry");
    }

    #[test]
    fn decide_winners_mean_weights_each_plan_bucket_once() {
        // Raw buckets 3 and 4 collide on plan bucket 4. Ungrouped, the
        // small-M specialist A would win the mean (2.53 vs 2.47) purely
        // because its best bucket is counted twice; grouped per plan
        // bucket, B wins (2.7 vs 2.25) — and B is what unmeasured large
        // buckets (e.g. M=1024 traffic) resolve to via the fallback.
        let measured = vec![(A, vec![3.1, 3.1, 1.4]), (B, vec![2.0, 2.0, 3.4])];
        let opts = SweepOptions {
            per_m: true,
            divergence_threshold: 0.10,
            ..Default::default()
        };
        let w = decide_winners(64, 0.25, &[3, 4, 16], &measured, &opts);
        let fallback = entry_for(&w, ShapeClass::of(64, 0.25)).unwrap();
        assert_eq!(fallback.kernel, B);
        assert!((fallback.flops_per_cycle - 2.7).abs() < 1e-9);
        // Plan bucket 4 still gets its specialist split (A: 3.1 vs B: 2.0).
        let split = entry_for(&w, ShapeClass::of_m(64, 0.25, 4)).unwrap();
        assert_eq!(split.kernel, A);
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn decide_winners_colliding_raw_buckets_cannot_contradict_each_other() {
        // Raw buckets 3 and 4 share M bucket 4. At raw 3 kernel A leads,
        // but at raw 4 (the bucket's actual size) B wins big: aggregated,
        // B leads the group (3.0 vs 2.0), so no split may be recorded —
        // pre-grouping, raw 3's divergence would have installed A for the
        // whole bucket even though the sweep measured it 4x slower at M=4.
        let measured = vec![(A, vec![3.0, 1.0]), (B, vec![2.0, 4.0])];
        let opts = SweepOptions {
            per_m: true,
            divergence_threshold: 0.08,
            ..Default::default()
        };
        let w = decide_winners(64, 0.25, &[3, 4], &measured, &opts);
        assert_eq!(w.len(), 1, "group winner equals mean winner → no split");
        assert_eq!(w[0].1.kernel, B);
    }

    #[test]
    fn per_m_sweep_retires_stale_m_entries_for_measured_buckets() {
        let c = cfg(); // buckets [1, 4], classes K=32 and K=64 at 25%
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        // Stale M-aware entries: one for a bucket this sweep measures
        // (must be retired — with a single candidate the fresh sweep can
        // never re-split, so only retirement can correct it), one for a
        // bucket it does not (must survive).
        let stale = TuneEntry::new(B, 9.9);
        table.insert(ShapeClass::of_m(32, 0.25, 1), stale.clone());
        table.insert(ShapeClass::of_m(32, 0.25, 64), stale.clone());
        let opts = SweepOptions {
            per_m: true,
            ..Default::default()
        };
        sweep_model_opts(&c, &c.batch_buckets, &[A], &timer, &mut table, &opts);
        // Bucket 1 was measured: the stale split is gone, so lookups fall
        // back to the fresh mean winner.
        assert_eq!(table.lookup_m(32, 0.25, 1).unwrap().kernel, A);
        // Bucket 64 was not measured: its entry is untouched.
        assert_eq!(table.lookup_m(32, 0.25, 64).unwrap(), &stale);
        // A non-per-M sweep must not retire race-recorded splits.
        let mut table2 = TuningTable::new();
        table2.insert(ShapeClass::of_m(32, 0.25, 1), stale.clone());
        sweep_model(&c, &c.batch_buckets, &[A], &timer, &mut table2);
        assert_eq!(table2.lookup_m(32, 0.25, 1).unwrap(), &stale);
    }

    #[test]
    fn per_m_sweep_records_fallback_plus_any_splits() {
        let c = cfg();
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        let opts = SweepOptions {
            per_m: true,
            ..Default::default()
        };
        let report = sweep_model_opts(&c, &c.batch_buckets, &[A, B], &timer, &mut table, &opts);
        // Whatever the timings did, every class has its M-agnostic
        // fallback, and any M-aware winner's bucket traces back to a
        // bucket this sweep actually measured.
        for i in 0..c.dims.len() - 1 {
            assert!(table.lookup(c.dims[i], c.sparsity).is_some());
        }
        for (class, _) in &report.winners {
            if let Some(m) = class.m_bucket {
                assert!(
                    c.batch_buckets.iter().any(|&b| m_bucket(b) == m as usize),
                    "M-aware entry recorded for unmeasured bucket {m}"
                );
            }
        }
    }

    #[test]
    fn reduce_geometry_records_only_divergent_non_default_winners() {
        let d = TileGeometry::DEFAULT;
        let g = TileGeometry::new(8, 1024);
        // Non-default wins by 50% > 8% → recorded.
        let (idx, rec) = reduce_geometry(&[d, g], &[vec![2.0, 2.0], vec![3.0, 3.0]], 0.08);
        assert_eq!((idx, rec), (1, Some(g)));
        // Non-default wins by only 2% → the default wins by fiat.
        let (idx, rec) = reduce_geometry(&[d, g], &[vec![2.0], vec![2.04]], 0.08);
        assert_eq!((idx, rec), (0, None));
        // Default outright best → no geometry recorded.
        let (idx, rec) = reduce_geometry(&[d, g], &[vec![5.0], vec![3.0]], 0.08);
        assert_eq!((idx, rec), (0, None));
        // Single candidate (every non-axis kernel) → trivially default.
        assert_eq!(reduce_geometry(&[d], &[vec![1.0]], 0.08), (0, None));
    }

    #[test]
    fn geometry_sweep_measures_axis_kernels_across_candidates() {
        let c = cfg();
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        let opts = SweepOptions {
            geometry: true,
            ..Default::default()
        };
        let report = sweep_model_opts(
            &c,
            &[1, 4],
            crate::kernels::kernel_ids(),
            &timer,
            &mut table,
            &opts,
        );
        let host = CpuCaps::host();
        let cands = crate::perf::geometry_candidates(&host);
        // Axis points carry the geometry they were measured at; non-axis
        // kernels never do.
        for p in &report.points {
            match p.geometry {
                Some(g) => {
                    assert!(p.kernel.descriptor().geometry, "{}", p.kernel);
                    assert!(cands.contains(&g), "unknown candidate {g:?}");
                }
                None => assert!(!p.kernel.descriptor().geometry, "{}", p.kernel),
            }
        }
        // Every admissible axis kernel was measured at every candidate.
        let axis: Vec<KernelId> =
            admissible_candidates(&host, crate::kernels::kernel_ids())
                .into_iter()
                .filter(|id| id.descriptor().geometry)
                .collect();
        assert!(!axis.is_empty(), "portable tile kernel is always admissible");
        for kid in axis {
            for &g in &cands {
                assert!(
                    report
                        .points
                        .iter()
                        .any(|p| p.kernel == kid && p.geometry == Some(g)),
                    "{kid} not measured at {g:?}"
                );
            }
        }
        // Recorded winners only ever carry divergent non-default
        // geometries from the candidate grid.
        for (_, entry) in &report.winners {
            if let Some(g) = entry.geometry {
                assert!(entry.kernel.descriptor().geometry);
                assert_ne!(g, TileGeometry::DEFAULT);
                assert!(cands.contains(&g));
            }
        }
        // Without --geometry nothing varies and nothing is recorded.
        let mut table2 = TuningTable::new();
        let report2 =
            sweep_model(&c, &[1], crate::kernels::kernel_ids(), &timer, &mut table2);
        assert!(report2.points.iter().all(|p| p.geometry.is_none()));
        assert!(report2.winners.iter().all(|(_, e)| e.geometry.is_none()));
    }
}
