//! `stgemm autotune sweep`: fill the tuning table for **every** layer ×
//! M-bucket of a model config in one run.
//!
//! The per-shape `autotune --save` flow persists one (K, sparsity) class
//! per invocation; a multi-layer serving config needs its whole set of
//! classes covered before the planner stops falling back to heuristics
//! (or the plan cache stops racing). The sweep walks the config's layer
//! shapes, measures every candidate kernel at each batch bucket, and
//! records one winner per class — the kernel with the best *mean*
//! flops/cycle across buckets, since the table is keyed by (K, sparsity)
//! only (M is performance-neutral per paper Fig 8, but the mean guards
//! against a kernel that only wins at a single outlier bucket).
//!
//! The serve-time background re-tune hook runs exactly this sweep on a
//! snapshot of the live table and installs the result.

use crate::autotune::table::{ShapeClass, TuneEntry, TuningTable};
use crate::bench::harness::measure_kernel;
use crate::kernels::KernelParams;
use crate::model::ModelConfig;
use crate::perf::timer::CycleTimer;

/// One (layer shape, bucket, kernel) measurement from a sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub layer: usize,
    pub k: usize,
    pub n: usize,
    pub sparsity: f32,
    pub bucket: usize,
    pub kernel: String,
    pub flops_per_cycle: f64,
}

/// Everything a sweep measured and decided.
#[derive(Debug, Clone, Default)]
pub struct SweepReport {
    /// Raw measurements, one per (class, bucket, kernel).
    pub points: Vec<SweepPoint>,
    /// Winner per shape class, in layer order (deduplicated: layers that
    /// share a class are measured once).
    pub winners: Vec<(ShapeClass, TuneEntry)>,
}

/// Measure `candidates` for every distinct (K, sparsity) class of `cfg`'s
/// layers at every bucket in `buckets`, record each class winner into
/// `table`, and return the full report. Existing entries for swept classes
/// are overwritten (fresh measurements beat stale ones); other entries are
/// left untouched.
pub fn sweep_model(
    cfg: &ModelConfig,
    buckets: &[usize],
    candidates: &[&str],
    timer: &CycleTimer,
    table: &mut TuningTable,
) -> SweepReport {
    assert!(!candidates.is_empty(), "sweep needs at least one candidate");
    let buckets: Vec<usize> = if buckets.is_empty() {
        vec![16]
    } else {
        buckets.to_vec()
    };
    let mut report = SweepReport::default();
    let mut seen: Vec<ShapeClass> = Vec::new();
    for layer in 0..cfg.dims.len() - 1 {
        let (k, n) = (cfg.dims[layer], cfg.dims[layer + 1]);
        let class = ShapeClass::of(k, cfg.sparsity);
        if seen.contains(&class) {
            continue;
        }
        seen.push(class);
        let mut best: Option<TuneEntry> = None;
        for &kernel in candidates {
            let mut sum = 0.0;
            for &m in &buckets {
                let meas = measure_kernel(
                    kernel,
                    m.max(1),
                    k,
                    n,
                    cfg.sparsity,
                    0xC0_FF_EE + layer as u64,
                    KernelParams::default(),
                    timer,
                );
                let fpc = meas.flops_per_cycle();
                report.points.push(SweepPoint {
                    layer,
                    k,
                    n,
                    sparsity: cfg.sparsity,
                    bucket: m.max(1),
                    kernel: kernel.to_string(),
                    flops_per_cycle: fpc,
                });
                sum += fpc;
            }
            let mean = sum / buckets.len() as f64;
            if best
                .as_ref()
                .map(|b| mean > b.flops_per_cycle)
                .unwrap_or(true)
            {
                best = Some(TuneEntry {
                    kernel: kernel.to_string(),
                    flops_per_cycle: mean,
                });
            }
        }
        let entry = best.expect("non-empty candidate set");
        table.insert(class, entry.clone());
        report.winners.push((class, entry));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::from_json(
            r#"{"name":"s","dims":[32,64,16],"sparsity":0.25,"seed":1,
                "batch_buckets":[1,4]}"#,
        )
        .unwrap()
    }

    #[test]
    fn sweep_covers_every_layer_class() {
        let c = cfg();
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        let report = sweep_model(
            &c,
            &c.batch_buckets,
            &["base_tcsc", "unrolled_tcsc_12"],
            &timer,
            &mut table,
        );
        // Two distinct classes (K=32 and K=64 at 25%), each covered.
        assert_eq!(report.winners.len(), 2);
        for i in 0..c.dims.len() - 1 {
            assert!(
                table.lookup(c.dims[i], c.sparsity).is_some(),
                "layer {i} class untuned after sweep"
            );
        }
        // classes × kernels × buckets raw points.
        assert_eq!(report.points.len(), 2 * 2 * 2);
        assert!(report.points.iter().all(|p| p.flops_per_cycle > 0.0));
    }

    #[test]
    fn shared_classes_are_measured_once() {
        let c = ModelConfig::from_json(
            r#"{"name":"s","dims":[64,64,64],"sparsity":0.25,"seed":1}"#,
        )
        .unwrap();
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        let report = sweep_model(&c, &[1], &["base_tcsc"], &timer, &mut table);
        assert_eq!(report.winners.len(), 1, "one class, measured once");
        assert_eq!(table.len(), 1);
    }

    #[test]
    fn empty_buckets_fall_back_to_default() {
        let c = cfg();
        let timer = CycleTimer::new(0, 1);
        let mut table = TuningTable::new();
        let report = sweep_model(&c, &[], &["base_tcsc"], &timer, &mut table);
        assert_eq!(report.points.len(), 2, "one default bucket per class");
        assert!(report.points.iter().all(|p| p.bucket == 16));
    }
}
