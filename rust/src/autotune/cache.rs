//! Parametric cache model.
//!
//! The paper's reasoning: the working set of the K4/M4 unrolled kernel is
//! `MU` rows of X (each K f32) plus `MU` rows of Y; 4 rows of 4096 floats
//! fit M1's 128 KB L1d, so B = 4096 is the largest block with no capacity
//! misses. The model below reproduces that arithmetic for any cache size
//! (host-detected when possible, M1 defaults otherwise).

/// Cache geometry used to predict unroll/block parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheModel {
    /// L1 data cache bytes per core.
    pub l1d_bytes: usize,
    /// Shared last-level cache bytes.
    pub llc_bytes: usize,
}

/// Apple M1 P-core geometry (the paper's target).
pub const APPLE_M1: CacheModel = CacheModel {
    l1d_bytes: 128 * 1024,
    llc_bytes: 12 * 1024 * 1024,
};

impl CacheModel {
    /// Detect the host's cache sizes from sysfs; fall back to M1 values.
    pub fn detect() -> CacheModel {
        fn read_kb(path: &str) -> Option<usize> {
            let s = std::fs::read_to_string(path).ok()?;
            let s = s.trim();
            let kb: usize = s.strip_suffix('K')?.parse().ok()?;
            Some(kb * 1024)
        }
        let base = "/sys/devices/system/cpu/cpu0/cache";
        let mut l1d = None;
        let mut llc = None;
        for i in 0..6 {
            let dir = format!("{base}/index{i}");
            let level = std::fs::read_to_string(format!("{dir}/level"))
                .ok()
                .and_then(|s| s.trim().parse::<u32>().ok());
            let ctype = std::fs::read_to_string(format!("{dir}/type"))
                .map(|s| s.trim().to_string())
                .unwrap_or_default();
            let size = read_kb(&format!("{dir}/size"));
            match (level, ctype.as_str(), size) {
                (Some(1), "Data", Some(b)) => l1d = Some(b),
                (Some(_), "Unified", Some(b)) => llc = Some(llc.unwrap_or(0).max(b)),
                _ => {}
            }
        }
        CacheModel {
            l1d_bytes: l1d.unwrap_or(APPLE_M1.l1d_bytes),
            llc_bytes: llc.unwrap_or(APPLE_M1.llc_bytes),
        }
    }

    /// Largest K for which `rows` rows of X + Y fit L1 (paper: 4 rows of
    /// 4096 f32 on M1 → 4096).
    pub fn max_k_for_rows(&self, rows: usize) -> usize {
        // rows·K f32 of X plus rows·(N-slice) of Y; the Y slice is small
        // compared to X in the paper's shapes, so model X only with a 25%
        // headroom factor (the paper's "without significant misses").
        let budget = self.l1d_bytes * 3 / 4;
        budget / (rows * std::mem::size_of::<f32>())
    }

    /// Paper rule generalized: recommended block size for the blocked
    /// kernels given MU rows, clamped to a power of two.
    pub fn recommended_block(&self, rows: usize) -> usize {
        let max_k = self.max_k_for_rows(rows.max(1));
        // Round down to a power of two (the paper picked 4096).
        let mut b = 1usize;
        while b * 2 <= max_k {
            b *= 2;
        }
        b.max(256)
    }

    /// Predicted optimal M-unroll for a given K: the largest MU ∈
    /// {1,2,4,8} whose working set still fits L1 (Figs 2–4's shape).
    pub fn predicted_mu(&self, k: usize) -> usize {
        for &mu in &[8usize, 4, 2] {
            if self.max_k_for_rows(mu) >= k {
                return mu;
            }
        }
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m1_block_rule_reproduces_4096() {
        // The paper's arithmetic: 4 rows in 128 KB L1 → B = 4096.
        assert_eq!(APPLE_M1.recommended_block(4), 4096);
    }

    #[test]
    fn predicted_mu_shrinks_with_k() {
        let m1 = APPLE_M1;
        // Figs 2–4: small K → high MU optimal; huge K → MU 1.
        assert!(m1.predicted_mu(1024) >= 4);
        assert!(m1.predicted_mu(16384) <= 2);
        let mut prev = usize::MAX;
        for k in [1024, 2048, 4096, 8192, 16384, 32768] {
            let mu = m1.predicted_mu(k);
            assert!(mu <= prev, "MU must be non-increasing in K");
            prev = mu;
        }
    }

    #[test]
    fn detect_returns_something_plausible() {
        let c = CacheModel::detect();
        assert!(c.l1d_bytes >= 8 * 1024 && c.l1d_bytes <= 16 * 1024 * 1024);
        assert!(c.llc_bytes >= c.l1d_bytes);
    }

    #[test]
    fn max_k_monotone_in_rows() {
        let c = APPLE_M1;
        assert!(c.max_k_for_rows(1) > c.max_k_for_rows(4));
        assert!(c.max_k_for_rows(4) > c.max_k_for_rows(8));
    }
}
