//! Miniature property-based testing framework (no proptest available
//! offline). Seeded generators + case iteration + first-failure reporting
//! with the generator seed so failures replay deterministically.
//!
//! ```
//! use stgemm::util::quickcheck::{props, Gen};
//! props("addition commutes", 100, |g| {
//!     let a = g.usize(0, 1000) as i64;
//!     let b = g.usize(0, 1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// A seeded generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            case_seed: seed,
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// A fresh seed derived from this generator (for seeding matrices etc.).
    pub fn seed(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Vector of f32s.
    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }
}

/// Run `cases` property cases. Panics (with the failing case seed) on the
/// first failure — `STGEMM_PROP_SEED=<n>` replays a single failing case.
pub fn props<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    // Replay mode: run exactly one case with the given seed.
    if let Ok(seed_str) = std::env::var("STGEMM_PROP_SEED") {
        if let Ok(seed) = seed_str.parse::<u64>() {
            let mut g = Gen::new(seed);
            prop(&mut g);
            return;
        }
    }
    let base = base_seed(name);
    for i in 0..cases {
        let case_seed = base.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed on case {i} (replay with \
                 STGEMM_PROP_SEED={case_seed}): {msg}"
            );
        }
    }
}

/// Stable seed derived from the property name (FNV-1a) so each property gets
/// an independent but reproducible case stream.
fn base_seed(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        props("trivial", 50, |g| {
            let _ = g.usize(0, 10);
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "STGEMM_PROP_SEED=")]
    fn failing_property_reports_seed() {
        props("always fails", 5, |_g| {
            assert_eq!(1, 2, "intentional");
        });
    }

    #[test]
    fn generator_ranges() {
        props("gen ranges", 200, |g| {
            let v = g.usize(3, 9);
            assert!((3..=9).contains(&v));
            let f = g.f32(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        });
    }

    #[test]
    fn choose_picks_member() {
        props("choose member", 100, |g| {
            let xs = [1, 5, 7];
            assert!(xs.contains(g.choose(&xs)));
        });
    }
}
