//! Fixed-size worker thread pool (no tokio available offline).
//!
//! Powers the HTTP server's connection handling, the wavefront pipeline
//! workers and parallel benchmark sweeps. Jobs are boxed closures in a
//! mutex-guarded deque; idle workers **park on a condvar** (they must
//! not burn the very efficiency cores the placement layer tries to
//! leave free), and waiting for idle is condvar-based with a short
//! bounded spin whose iterations are counted — the counter is the
//! regression test that the old spin+yield loop stays gone.
//!
//! Placement: [`ThreadPool::with_placement`] pins each worker at spawn
//! according to a [`PlacementPolicy`] over a [`CpuTopology`]
//! (best-effort — see [`crate::util::affinity`]), records the
//! per-worker outcome for `/status`, and makes
//! [`ThreadPool::run_scoped_workers`] *assigned*: logical worker `i`
//! runs on pool thread `i % size`, so a band assigned to worker `i`
//! lands on the same pinned core (and therefore the same L2) every
//! forward pass. Unplaced pools keep the original any-worker queue.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use crate::perf::topology::CpuTopology;
use crate::util::affinity::{core_set, pin_current_thread, PinOutcome, PlacementPolicy};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Where one pool worker ended up: its policy-assigned core set and
/// whether the OS accepted the pin. Surfaced through
/// [`ThreadPool::placements`] into `/status` placement rows.
#[derive(Debug, Clone)]
pub struct WorkerPlacement {
    pub worker: usize,
    pub cores: Vec<usize>,
    pub outcome: PinOutcome,
}

struct PoolState {
    /// Any-worker jobs, FIFO.
    queue: VecDeque<Job>,
    /// Per-worker assigned jobs (placement-sticky routing).
    assigned: Vec<VecDeque<Job>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers park here when both queues are empty.
    work_cv: Condvar,
    /// `wait_idle` parks here; the worker finishing the last in-flight
    /// job notifies (under the state lock, so wakeups can't be missed).
    idle_cv: Condvar,
    in_flight: AtomicUsize,
    panics: AtomicUsize,
    /// Spin iterations burned inside `wait_idle` before parking.
    busy_wait_iters: AtomicU64,
}

/// A fixed pool of worker threads executing submitted closures.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    shared: Arc<Shared>,
    policy: PlacementPolicy,
    placements: Arc<Mutex<Vec<WorkerPlacement>>>,
}

/// `wait_idle` spins at most this many yields before parking on the
/// idle condvar. Small: just enough to absorb a job that is already
/// retiring without a syscall.
const IDLE_SPIN_LIMIT: u64 = 64;

impl ThreadPool {
    /// Create an unplaced pool with `size` workers (`size >= 1`):
    /// threads land wherever the OS puts them, exactly as before.
    pub fn new(size: usize) -> ThreadPool {
        Self::spawn(size, PlacementPolicy::None, None)
    }

    /// Create a pool whose workers pin themselves at spawn according to
    /// `policy` over `topo`. Pinning is best-effort: a worker whose pin
    /// fails (or a platform with no pinning primitive) runs unpinned
    /// and says so in [`ThreadPool::placements`].
    pub fn with_placement(size: usize, policy: PlacementPolicy, topo: &CpuTopology) -> ThreadPool {
        Self::spawn(size, policy, Some(Arc::new(topo.clone())))
    }

    fn spawn(size: usize, policy: PlacementPolicy, topo: Option<Arc<CpuTopology>>) -> ThreadPool {
        assert!(size >= 1, "thread pool needs at least one worker");
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                assigned: (0..size).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            in_flight: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
            busy_wait_iters: AtomicU64::new(0),
        });
        let placements = Arc::new(Mutex::new(Vec::with_capacity(size)));
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let placements = Arc::clone(&placements);
                let topo = topo.clone();
                thread::Builder::new()
                    .name(format!("stgemm-worker-{i}"))
                    .spawn(move || {
                        if let Some(topo) = &topo {
                            let cores = core_set(policy, topo, i, size);
                            let outcome = if policy == PlacementPolicy::None {
                                PinOutcome::Unrestricted
                            } else {
                                pin_current_thread(topo, &cores)
                            };
                            placements
                                .lock()
                                .unwrap_or_else(|e| e.into_inner())
                                .push(WorkerPlacement {
                                    worker: i,
                                    cores,
                                    outcome,
                                });
                        }
                        Self::worker_loop(i, &shared);
                    })
                    .expect("failed to spawn worker")
            })
            .collect();
        if topo.is_some() {
            // Placement registration is each worker's first pre-loop step;
            // waiting for every row here (microseconds — a few syscalls
            // per worker) makes `placements()` deterministic for status
            // rows and tests instead of racing worker startup.
            while placements.lock().unwrap_or_else(|e| e.into_inner()).len() < size {
                thread::yield_now();
            }
        }
        ThreadPool {
            workers,
            shared,
            policy,
            placements,
        }
    }

    fn worker_loop(index: usize, shared: &Shared) {
        loop {
            let job = {
                let mut s = shared.state.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    if let Some(job) = s.assigned[index].pop_front() {
                        break Some(job);
                    }
                    if let Some(job) = s.queue.pop_front() {
                        break Some(job);
                    }
                    if s.shutdown {
                        break None;
                    }
                    // Park; no CPU burned while the pool is idle.
                    s = shared.work_cv.wait(s).unwrap_or_else(|e| e.into_inner());
                }
            };
            let Some(job) = job else { break };
            // Isolate panics: a panicking job must not take the worker
            // (or the pool) down.
            let res = catch_unwind(AssertUnwindSafe(job));
            if res.is_err() {
                shared.panics.fetch_add(1, Ordering::SeqCst);
            }
            if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                // Last in-flight job: wake idle waiters. Taking the state
                // lock orders this notify after any waiter's check of
                // `in_flight`, so the wakeup cannot be missed.
                let _guard = shared.state.lock().unwrap_or_else(|e| e.into_inner());
                shared.idle_cv.notify_all();
            }
        }
    }

    fn submit(&self, job: Job, target: Option<usize>) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        let mut s = self
            .shared
            .state
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        assert!(!s.shutdown, "thread pool has shut down");
        match target {
            Some(worker) => {
                let slot = worker % self.workers.len();
                s.assigned[slot].push_back(job);
                // An assigned job wakes everyone: only worker `slot` can
                // take it, but a notify_one might land on a different
                // parked thread.
                drop(s);
                self.shared.work_cv.notify_all();
            }
            None => {
                s.queue.push_back(job);
                drop(s);
                self.shared.work_cv.notify_one();
            }
        }
    }

    /// Submit a job for execution on any worker.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.submit(Box::new(job), None);
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.shared.in_flight.load(Ordering::SeqCst)
    }

    /// Number of jobs that panicked (isolated, workers survive).
    pub fn panic_count(&self) -> usize {
        self.shared.panics.load(Ordering::SeqCst)
    }

    /// Spin iterations burned inside [`ThreadPool::wait_idle`] across
    /// the pool's lifetime — the busy-wait regression gauge: an idle
    /// pool contributes zero, and each wait adds at most the bounded
    /// spin before parking.
    pub fn busy_wait_iters(&self) -> u64 {
        self.shared.busy_wait_iters.load(Ordering::SeqCst)
    }

    /// The placement policy this pool's workers were spawned under.
    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Per-worker placement outcomes, worker order. Empty for pools
    /// created with [`ThreadPool::new`] (no topology — nothing was even
    /// attempted).
    pub fn placements(&self) -> Vec<WorkerPlacement> {
        let mut rows = self
            .placements
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone();
        rows.sort_by_key(|p| p.worker);
        rows
    }

    /// Number of workers the OS actually pinned.
    pub fn pinned_workers(&self) -> usize {
        self.placements()
            .iter()
            .filter(|p| p.outcome == PinOutcome::Pinned)
            .count()
    }

    /// Whether scoped fan-outs route job `i` to pool thread `i % size`
    /// (true for the per-core placements, `Compact`/`Spread`, where each
    /// pool thread is pinned to one core — band → worker → core then
    /// stays sticky across passes). Set-restricted (`PerfCoresFirst`)
    /// and unplaced pools keep any-worker routing: the OS balances
    /// within the allowed set, and strict routing would serialize
    /// concurrent forwards sharing the pool.
    pub fn sticky_routing(&self) -> bool {
        matches!(
            self.policy,
            PlacementPolicy::Compact | PlacementPolicy::Spread
        )
    }

    /// Scoped fork-join: run a batch of jobs that may borrow non-`'static`
    /// data, blocking until every one of them has finished. This is what
    /// lets the GEMM partitioner hand workers `&mut` row slices of the
    /// caller's output matrix through a pool of long-lived threads instead
    /// of spawning OS threads per call.
    ///
    /// Returns the number of jobs that panicked (0 = all completed).
    /// Scoped-job panics are caught *here* and reported through the return
    /// value — updated under the same lock as the completion latch, so the
    /// count is exact by the time this returns (they do not feed
    /// [`ThreadPool::panic_count`], which stays for fire-and-forget jobs).
    ///
    /// Safety of the internal lifetime erasure: this function does not
    /// return until all jobs have completed — the latch is decremented
    /// whether a job returns or panics — so no job can outlive the borrows
    /// it captures.
    ///
    /// Do not call from inside a pool worker: a saturated pool would
    /// deadlock waiting on itself.
    #[must_use = "a non-zero return means worker jobs panicked"]
    pub fn run_scoped<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) -> usize {
        self.run_scoped_routed(jobs, false)
    }

    /// Scoped fork-join with sticky routing regardless of policy: job
    /// `i` runs on pool thread `i % size`. Used by the arena's
    /// first-touch pass so page ownership matches the worker that will
    /// stream the band every forward pass. Same completion/panic
    /// semantics as [`ThreadPool::run_scoped`].
    #[must_use = "a non-zero return means worker jobs panicked"]
    pub fn run_scoped_assigned<'scope>(
        &self,
        jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>,
    ) -> usize {
        self.run_scoped_routed(jobs, true)
    }

    fn run_scoped_routed<'scope>(
        &self,
        jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>,
        assign: bool,
    ) -> usize {
        if jobs.is_empty() {
            return 0;
        }
        // (jobs remaining, jobs panicked)
        let latch = Arc::new((Mutex::new((jobs.len(), 0usize)), Condvar::new()));
        for (i, job) in jobs.into_iter().enumerate() {
            // SAFETY: see above — the latch wait below keeps every borrow
            // captured by `job` alive until the job has run (or panicked).
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'scope>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let latch = Arc::clone(&latch);
            let wrapped: Job = Box::new(move || {
                let panicked = catch_unwind(AssertUnwindSafe(job)).is_err();
                let (state, cv) = &*latch;
                let mut s = state.lock().unwrap_or_else(|e| e.into_inner());
                s.0 -= 1;
                if panicked {
                    s.1 += 1;
                }
                cv.notify_all();
            });
            self.submit(wrapped, if assign { Some(i) } else { None });
        }
        let (state, cv) = &*latch;
        let mut s = state.lock().unwrap_or_else(|e| e.into_inner());
        while s.0 > 0 {
            s = cv.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        s.1
    }

    /// Scoped **worker-loop** fan-out: run `n` copies of `worker` (each
    /// handed its worker index) and block until all of them return. Where
    /// [`ThreadPool::run_scoped`] submits one closure per pre-assigned
    /// chunk, this is the pull-model generalization the wavefront pipeline
    /// scheduler needs: each copy of `worker` loops pulling `(layer, band)`
    /// tasks from a shared scheduler until the task graph is drained, so
    /// one forward pass costs `n` pool jobs instead of layers × bands.
    ///
    /// On a pool spawned with a per-core placement (`Compact`/`Spread`),
    /// copy `i` is routed to pool thread `i % size`, so the logical
    /// worker index corresponds to a pinned core and band → worker
    /// assignments stay cluster-sticky across passes. Unplaced (and
    /// set-restricted) pools keep any-worker routing.
    ///
    /// The copies must not depend on each other to make progress (any
    /// single worker must be able to drain the shared work source alone):
    /// on a saturated pool the copies may run *sequentially*, and a worker
    /// that blocks waiting on a sibling would deadlock.
    ///
    /// Returns the number of workers that panicked (0 = all completed);
    /// borrows in `worker` stay alive until every copy has finished, same
    /// as [`ThreadPool::run_scoped`].
    #[must_use = "a non-zero return means worker jobs panicked"]
    pub fn run_scoped_workers<F>(&self, n: usize, worker: F) -> usize
    where
        F: Fn(usize) + Send + Sync,
    {
        let worker = &worker;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
            .map(|i| Box::new(move || worker(i)) as Box<dyn FnOnce() + Send + '_>)
            .collect();
        self.run_scoped_routed(jobs, self.sticky_routing())
    }

    /// Block until every submitted job has finished. A short bounded
    /// spin (counted in [`ThreadPool::busy_wait_iters`]) absorbs jobs
    /// that are already retiring; past it the caller parks on a condvar
    /// until the last in-flight job notifies.
    pub fn wait_idle(&self) {
        let mut spins = 0u64;
        while self.in_flight() > 0 && spins < IDLE_SPIN_LIMIT {
            spins += 1;
            thread::yield_now();
        }
        if spins > 0 {
            self.shared.busy_wait_iters.fetch_add(spins, Ordering::SeqCst);
        }
        if self.in_flight() == 0 {
            return;
        }
        let mut s = self
            .shared
            .state
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        while self.in_flight() > 0 {
            // Timed wait purely as a belt: correctness comes from the
            // under-lock notify in the worker loop.
            let (guard, _timeout) = self
                .shared
                .idle_cv
                .wait_timeout(s, Duration::from_millis(50))
                .unwrap_or_else(|e| e.into_inner());
            s = guard;
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut s = self
                .shared
                .state
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            s.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f` over `items` in parallel on `threads` workers, preserving order.
/// Convenience used by benchmark sweeps.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    let pool = ThreadPool::new(threads.max(1));
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<R>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let r = f(item);
            results.lock().unwrap()[i] = Some(r);
        });
    }
    pool.wait_idle();
    Arc::try_unwrap(results)
        .ok()
        .expect("pool idle, no other refs")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn survives_panicking_jobs() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
        // Pool still works afterwards.
        let flag = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&flag);
        pool.execute(move || {
            f.store(7, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(flag.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn run_scoped_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 16];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(4)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for v in chunk.iter_mut() {
                        *v = i as u64 + 1;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        assert_eq!(pool.run_scoped(jobs), 0);
        assert_eq!(data[0], 1);
        assert_eq!(data[5], 2);
        assert_eq!(data[15], 4);
    }

    #[test]
    fn run_scoped_reports_panicking_job() {
        let pool = ThreadPool::new(2);
        let flag = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&flag);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("scoped boom")),
            Box::new(move || {
                f.store(11, Ordering::SeqCst);
            }),
        ];
        // Must return, with the panic reported exactly in the return value.
        assert_eq!(pool.run_scoped(jobs), 1);
        assert_eq!(flag.load(Ordering::SeqCst), 11);
        // Scoped panics are caught locally, not via the pool counter.
        assert_eq!(pool.panic_count(), 0);
    }

    #[test]
    fn run_scoped_workers_share_a_task_queue() {
        let pool = ThreadPool::new(4);
        let next = AtomicU64::new(0);
        let done = AtomicU64::new(0);
        // Any worker can drain the queue alone; together they cover it
        // exactly once.
        assert_eq!(
            pool.run_scoped_workers(4, |_worker| {
                while next.fetch_add(1, Ordering::SeqCst) < 100 {
                    done.fetch_add(1, Ordering::SeqCst);
                }
            }),
            0
        );
        assert_eq!(done.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn run_scoped_workers_reports_panics() {
        let pool = ThreadPool::new(2);
        let survivors = AtomicU64::new(0);
        let panicked = pool.run_scoped_workers(3, |worker| {
            if worker == 1 {
                panic!("worker boom");
            }
            survivors.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(panicked, 1);
        assert_eq!(survivors.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..64).collect::<Vec<u64>>(), 8, |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not deadlock; shutdown after queue drains or mid-queue is fine
        // At least the in-flight jobs at drop time completed; counter ≤ 10.
        assert!(counter.load(Ordering::SeqCst) <= 10);
    }

    #[test]
    fn idle_wait_burns_no_busy_iterations() {
        // Satellite regression: waiting on an idle pool must not spin at
        // all, and waiting on a busy pool spins at most the bound before
        // parking on the condvar.
        let pool = ThreadPool::new(4);
        pool.wait_idle();
        assert_eq!(pool.busy_wait_iters(), 0, "idle pool: zero busy-wait");
        let gate = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let g = Arc::clone(&gate);
            pool.execute(move || {
                while g.load(Ordering::SeqCst) == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            });
        }
        let opener = {
            let g = Arc::clone(&gate);
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                g.store(1, Ordering::SeqCst);
            })
        };
        pool.wait_idle();
        opener.join().unwrap();
        assert_eq!(pool.in_flight(), 0);
        assert!(
            pool.busy_wait_iters() <= IDLE_SPIN_LIMIT,
            "one long wait spins at most the bound, then parks (got {})",
            pool.busy_wait_iters()
        );
    }

    #[test]
    fn placed_pool_reports_per_worker_placement() {
        let topo = CpuTopology::apple_like();
        let pool = ThreadPool::with_placement(4, PlacementPolicy::Compact, &topo);
        // Wait for all workers to have registered (they push at spawn,
        // before entering the loop; run a barrier pass to be sure).
        assert_eq!(pool.run_scoped_workers(4, |_| {}), 0);
        let rows = pool.placements();
        assert_eq!(rows.len(), 4);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.worker, i);
            assert_eq!(row.cores, vec![i], "compact on apple_like: one core each");
            assert!(!row.outcome.as_str().is_empty());
        }
        assert_eq!(pool.policy(), PlacementPolicy::Compact);
        // Unplaced pools attempted nothing.
        assert!(ThreadPool::new(2).placements().is_empty());
    }

    #[test]
    fn assigned_routing_lands_copy_on_its_thread() {
        // On a Compact-placed pool, run_scoped_workers copy i must run on
        // pool thread i (thread name carries the index).
        let topo = CpuTopology::flat(4);
        let pool = ThreadPool::with_placement(4, PlacementPolicy::Compact, &topo);
        let names = Mutex::new(vec![String::new(); 4]);
        assert_eq!(
            pool.run_scoped_workers(4, |i| {
                let name = std::thread::current().name().unwrap_or("").to_string();
                names.lock().unwrap()[i] = name;
                // Hold the slot briefly so copies can't collapse onto one
                // fast thread by finishing before the next is submitted.
                std::thread::sleep(std::time::Duration::from_millis(2));
            }),
            0
        );
        let names = names.into_inner().unwrap();
        for (i, name) in names.iter().enumerate() {
            assert_eq!(name, &format!("stgemm-worker-{i}"), "copy {i} pinned to thread {i}");
        }
    }
}
