//! Fixed-size worker thread pool (no tokio available offline).
//!
//! Powers the HTTP server's connection handling and parallel benchmark
//! sweeps. Jobs are boxed closures delivered over an mpsc channel guarded by
//! a mutex (the classic "channel of jobs" pool from the Rust book, hardened
//! with graceful shutdown and panic isolation).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing submitted closures.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: mpsc::Sender<Message>,
    in_flight: Arc<AtomicUsize>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (`size >= 1`).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size >= 1, "thread pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                let panics = Arc::clone(&panics);
                thread::Builder::new()
                    .name(format!("stgemm-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().expect("pool channel poisoned");
                            guard.recv()
                        };
                        match msg {
                            Ok(Message::Run(job)) => {
                                // Isolate panics: a panicking job must not
                                // take the worker (or the pool) down.
                                let res = catch_unwind(AssertUnwindSafe(job));
                                if res.is_err() {
                                    panics.fetch_add(1, Ordering::SeqCst);
                                }
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Ok(Message::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("failed to spawn worker")
            })
            .collect();
        ThreadPool {
            workers,
            tx,
            in_flight,
            panics,
        }
    }

    /// Submit a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Message::Run(Box::new(job)))
            .expect("thread pool has shut down");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Number of jobs that panicked (isolated, workers survive).
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Block until every submitted job has finished (spin + yield; used by
    /// tests and batch drivers, not the server hot path).
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            thread::yield_now();
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f` over `items` in parallel on `threads` workers, preserving order.
/// Convenience used by benchmark sweeps.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    let pool = ThreadPool::new(threads.max(1));
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<R>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let r = f(item);
            results.lock().unwrap()[i] = Some(r);
        });
    }
    pool.wait_idle();
    Arc::try_unwrap(results)
        .ok()
        .expect("pool idle, no other refs")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn survives_panicking_jobs() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
        // Pool still works afterwards.
        let flag = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&flag);
        pool.execute(move || {
            f.store(7, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(flag.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..64).collect::<Vec<u64>>(), 8, |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not deadlock; shutdown after queue drains or mid-queue is fine
        // At least the in-flight jobs at drop time completed; counter ≤ 10.
        assert!(counter.load(Ordering::SeqCst) <= 10);
    }
}
