//! Fixed-size worker thread pool (no tokio available offline).
//!
//! Powers the HTTP server's connection handling and parallel benchmark
//! sweeps. Jobs are boxed closures delivered over an mpsc channel guarded by
//! a mutex (the classic "channel of jobs" pool from the Rust book, hardened
//! with graceful shutdown and panic isolation).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing submitted closures.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: mpsc::Sender<Message>,
    in_flight: Arc<AtomicUsize>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (`size >= 1`).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size >= 1, "thread pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                let panics = Arc::clone(&panics);
                thread::Builder::new()
                    .name(format!("stgemm-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().expect("pool channel poisoned");
                            guard.recv()
                        };
                        match msg {
                            Ok(Message::Run(job)) => {
                                // Isolate panics: a panicking job must not
                                // take the worker (or the pool) down.
                                let res = catch_unwind(AssertUnwindSafe(job));
                                if res.is_err() {
                                    panics.fetch_add(1, Ordering::SeqCst);
                                }
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Ok(Message::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("failed to spawn worker")
            })
            .collect();
        ThreadPool {
            workers,
            tx,
            in_flight,
            panics,
        }
    }

    /// Submit a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Message::Run(Box::new(job)))
            .expect("thread pool has shut down");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Number of jobs that panicked (isolated, workers survive).
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Scoped fork-join: run a batch of jobs that may borrow non-`'static`
    /// data, blocking until every one of them has finished. This is what
    /// lets the GEMM partitioner hand workers `&mut` row slices of the
    /// caller's output matrix through a pool of long-lived threads instead
    /// of spawning OS threads per call.
    ///
    /// Returns the number of jobs that panicked (0 = all completed).
    /// Scoped-job panics are caught *here* and reported through the return
    /// value — updated under the same lock as the completion latch, so the
    /// count is exact by the time this returns (they do not feed
    /// [`ThreadPool::panic_count`], which stays for fire-and-forget jobs).
    ///
    /// Safety of the internal lifetime erasure: this function does not
    /// return until all jobs have completed — the latch is decremented
    /// whether a job returns or panics — so no job can outlive the borrows
    /// it captures.
    ///
    /// Do not call from inside a pool worker: a saturated pool would
    /// deadlock waiting on itself.
    #[must_use = "a non-zero return means worker jobs panicked"]
    pub fn run_scoped<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) -> usize {
        if jobs.is_empty() {
            return 0;
        }
        // (jobs remaining, jobs panicked)
        let latch = Arc::new((Mutex::new((jobs.len(), 0usize)), Condvar::new()));
        for job in jobs {
            // SAFETY: see above — the latch wait below keeps every borrow
            // captured by `job` alive until the job has run (or panicked).
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'scope>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let latch = Arc::clone(&latch);
            self.execute(move || {
                let panicked = catch_unwind(AssertUnwindSafe(job)).is_err();
                let (state, cv) = &*latch;
                let mut s = state.lock().unwrap_or_else(|e| e.into_inner());
                s.0 -= 1;
                if panicked {
                    s.1 += 1;
                }
                cv.notify_all();
            });
        }
        let (state, cv) = &*latch;
        let mut s = state.lock().unwrap_or_else(|e| e.into_inner());
        while s.0 > 0 {
            s = cv.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        s.1
    }

    /// Scoped **worker-loop** fan-out: run `n` copies of `worker` (each
    /// handed its worker index) and block until all of them return. Where
    /// [`ThreadPool::run_scoped`] submits one closure per pre-assigned
    /// chunk, this is the pull-model generalization the wavefront pipeline
    /// scheduler needs: each copy of `worker` loops pulling `(layer, band)`
    /// tasks from a shared scheduler until the task graph is drained, so
    /// one forward pass costs `n` pool jobs instead of layers × bands.
    ///
    /// The copies must not depend on each other to make progress (any
    /// single worker must be able to drain the shared work source alone):
    /// on a saturated pool the copies may run *sequentially*, and a worker
    /// that blocks waiting on a sibling would deadlock.
    ///
    /// Returns the number of workers that panicked (0 = all completed);
    /// borrows in `worker` stay alive until every copy has finished, same
    /// as [`ThreadPool::run_scoped`].
    #[must_use = "a non-zero return means worker jobs panicked"]
    pub fn run_scoped_workers<F>(&self, n: usize, worker: F) -> usize
    where
        F: Fn(usize) + Send + Sync,
    {
        let worker = &worker;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
            .map(|i| Box::new(move || worker(i)) as Box<dyn FnOnce() + Send + '_>)
            .collect();
        self.run_scoped(jobs)
    }

    /// Block until every submitted job has finished (spin + yield; used by
    /// tests and batch drivers, not the server hot path).
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            thread::yield_now();
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f` over `items` in parallel on `threads` workers, preserving order.
/// Convenience used by benchmark sweeps.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    let pool = ThreadPool::new(threads.max(1));
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<R>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let r = f(item);
            results.lock().unwrap()[i] = Some(r);
        });
    }
    pool.wait_idle();
    Arc::try_unwrap(results)
        .ok()
        .expect("pool idle, no other refs")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn survives_panicking_jobs() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
        // Pool still works afterwards.
        let flag = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&flag);
        pool.execute(move || {
            f.store(7, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(flag.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn run_scoped_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 16];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(4)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for v in chunk.iter_mut() {
                        *v = i as u64 + 1;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        assert_eq!(pool.run_scoped(jobs), 0);
        assert_eq!(data[0], 1);
        assert_eq!(data[5], 2);
        assert_eq!(data[15], 4);
    }

    #[test]
    fn run_scoped_reports_panicking_job() {
        let pool = ThreadPool::new(2);
        let flag = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&flag);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("scoped boom")),
            Box::new(move || {
                f.store(11, Ordering::SeqCst);
            }),
        ];
        // Must return, with the panic reported exactly in the return value.
        assert_eq!(pool.run_scoped(jobs), 1);
        assert_eq!(flag.load(Ordering::SeqCst), 11);
        // Scoped panics are caught locally, not via the pool counter.
        assert_eq!(pool.panic_count(), 0);
    }

    #[test]
    fn run_scoped_workers_share_a_task_queue() {
        let pool = ThreadPool::new(4);
        let next = AtomicU64::new(0);
        let done = AtomicU64::new(0);
        // Any worker can drain the queue alone; together they cover it
        // exactly once.
        assert_eq!(
            pool.run_scoped_workers(4, |_worker| {
                while next.fetch_add(1, Ordering::SeqCst) < 100 {
                    done.fetch_add(1, Ordering::SeqCst);
                }
            }),
            0
        );
        assert_eq!(done.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn run_scoped_workers_reports_panics() {
        let pool = ThreadPool::new(2);
        let survivors = AtomicU64::new(0);
        let panicked = pool.run_scoped_workers(3, |worker| {
            if worker == 1 {
                panic!("worker boom");
            }
            survivors.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(panicked, 1);
        assert_eq!(survivors.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..64).collect::<Vec<u64>>(), 8, |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not deadlock; shutdown after queue drains or mid-queue is fine
        // At least the in-flight jobs at drop time completed; counter ≤ 10.
        assert!(counter.load(Ordering::SeqCst) <= 10);
    }
}
