//! Deterministic pseudo-random number generation.
//!
//! Xoshiro256** seeded through SplitMix64 — the standard pairing recommended
//! by the xoshiro authors. No external `rand` crate is available offline, and
//! determinism across runs/platforms matters for reproducible benchmarks, so
//! this is a feature, not a stopgap.

/// SplitMix64 — used to expand a single `u64` seed into the Xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid for xoshiro; SplitMix64 cannot produce
        // four consecutive zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mulwide(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi as usize;
            }
        }
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 mantissa bits → exactly representable uniform grid.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (polar form not needed for our use).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(f32::MIN_POSITIVE);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // For small k relative to n use a set-free partial shuffle on an
        // index vector; n is at most K (≤ 16384 in paper workloads) so the
        // allocation is cheap relative to matrix construction.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[inline]
fn mulwide(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f32_mean_is_centered() {
        let mut r = Rng::new(5);
        let mean: f32 = (0..10_000).map(|_| r.f32()).sum::<f32>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(100, 40);
        assert_eq!(s.len(), 40);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40, "indices must be distinct");
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_full_population() {
        let mut r = Rng::new(9);
        let mut s = r.sample_indices(16, 16);
        s.sort_unstable();
        assert_eq!(s, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
