//! Worker **placement**: map a logical worker index to a core set on a
//! [`CpuTopology`] and pin the calling thread to it.
//!
//! Placement never changes *what* runs — only *where*. Every policy is a
//! pure function `(topology, worker, workers) → cores`, applied
//! best-effort at thread spawn:
//!
//! - **Linux**: `sched_setaffinity(0, ...)` on the calling thread.
//! - **macOS**: explicit core ids are not honored, so placement maps to
//!   a QoS class (`USER_INTERACTIVE` for performance-core sets,
//!   `UTILITY` for efficiency-core sets) plus a
//!   `THREAD_AFFINITY_POLICY` tag derived from the target cluster so
//!   same-cluster workers share an L2 ("affinity tag" = scheduler hint
//!   to co-locate).
//! - **Everywhere else**: a no-op that *says so* — [`PinOutcome::Unsupported`]
//!   feeds the coordinator's `placement_unsupported` gauge, so a silent
//!   fallback is still a visible fallback.
//!
//! Pinning failures are likewise reported, never fatal: a worker that
//! cannot pin runs exactly the unpinned path (the bitwise-identity
//! property tests in `tests/placement.rs` hold across all of it).

use crate::perf::topology::{ClusterKind, CpuTopology};

/// How worker threads map onto cores. Parsed from `--placement`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Restrict every worker to the performance-core set (no per-core
    /// pinning inside it). The default for serving: keeps the wavefront
    /// off efficiency cores while letting the OS balance within the
    /// P-cluster.
    #[default]
    PerfCoresFirst,
    /// One core per worker, filling each cluster densely before
    /// spilling to the next (performance clusters first). Maximizes
    /// shared-L2 locality between adjacent workers.
    Compact,
    /// One core per worker, round-robin across clusters. Maximizes
    /// aggregate cache/bandwidth at the cost of locality.
    Spread,
    /// Leave every thread where the OS puts it (`--no-pin`).
    None,
}

impl PlacementPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            PlacementPolicy::PerfCoresFirst => "perf",
            PlacementPolicy::Compact => "compact",
            PlacementPolicy::Spread => "spread",
            PlacementPolicy::None => "none",
        }
    }

    /// Parse a CLI spelling. Accepts the `as_str` forms plus a few
    /// obvious aliases.
    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "perf" | "perf-cores-first" | "pcores" | "p" => Some(PlacementPolicy::PerfCoresFirst),
            "compact" => Some(PlacementPolicy::Compact),
            "spread" => Some(PlacementPolicy::Spread),
            "none" | "off" | "no-pin" => Some(PlacementPolicy::None),
            _ => None,
        }
    }

    /// All policies, for sweeping in tests/benches.
    pub fn all() -> [PlacementPolicy; 4] {
        [
            PlacementPolicy::PerfCoresFirst,
            PlacementPolicy::Compact,
            PlacementPolicy::Spread,
            PlacementPolicy::None,
        ]
    }
}

impl std::str::FromStr for PlacementPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        PlacementPolicy::parse(s)
            .ok_or_else(|| format!("unknown placement policy '{s}' (perf|compact|spread|none)"))
    }
}

impl std::fmt::Display for PlacementPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// What actually happened when a thread asked to be pinned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PinOutcome {
    /// The OS accepted the affinity request.
    Pinned,
    /// Policy was `None` or the core set covers every core — nothing to
    /// ask for.
    Unrestricted,
    /// The platform has no pinning primitive (the portable no-op).
    Unsupported,
    /// The platform call failed; the thread runs unpinned.
    Failed,
}

impl PinOutcome {
    pub fn as_str(&self) -> &'static str {
        match self {
            PinOutcome::Pinned => "pinned",
            PinOutcome::Unrestricted => "unrestricted",
            PinOutcome::Unsupported => "unsupported",
            PinOutcome::Failed => "failed",
        }
    }
}

/// The core set policy `policy` assigns to worker `worker` of
/// `workers` on `topo`. Always non-empty, always a subset of the
/// topology's cores, ascending; pure (property-tested in
/// `tests/placement.rs` across policies × workers 1..32).
pub fn core_set(
    policy: PlacementPolicy,
    topo: &CpuTopology,
    worker: usize,
    workers: usize,
) -> Vec<usize> {
    let all_cores: Vec<usize> = topo
        .clusters
        .iter()
        .flat_map(|c| c.cores.iter().copied())
        .collect();
    if all_cores.is_empty() {
        return vec![0];
    }
    match policy {
        PlacementPolicy::None => {
            let mut cores = all_cores;
            cores.sort_unstable();
            cores
        }
        PlacementPolicy::PerfCoresFirst => {
            let mut perf = topo.perf_cores();
            if perf.is_empty() {
                perf = all_cores;
            }
            perf.sort_unstable();
            perf
        }
        PlacementPolicy::Compact => {
            // Dense fill: cluster 0's cores in order, then cluster 1's,
            // wrapping when workers exceed cores.
            vec![all_cores[worker % all_cores.len()]]
        }
        PlacementPolicy::Spread => {
            // Round-robin over clusters: worker i takes the next unused
            // core of cluster (i mod clusters), wrapping within each.
            let nclusters = topo.clusters.len().max(1);
            let cluster = &topo.clusters[worker % nclusters];
            let round = worker / nclusters;
            vec![cluster.cores[round % cluster.cores.len()]]
        }
    }
}

/// Whether this build can pin threads at all (compile-time fact — the
/// gauge behind the README's "no-op fallback" guarantee).
pub fn platform_supported() -> bool {
    cfg!(any(target_os = "linux", target_os = "macos"))
}

/// Pin the calling thread to `cores` of `topo`, best-effort. `cores`
/// should come from [`core_set`]; an empty or all-core set degrades to
/// [`PinOutcome::Unrestricted`].
pub fn pin_current_thread(topo: &CpuTopology, cores: &[usize]) -> PinOutcome {
    if cores.is_empty() || cores.len() >= topo.num_cores() {
        return PinOutcome::Unrestricted;
    }
    pin_impl(topo, cores)
}

#[cfg(target_os = "linux")]
fn pin_impl(_topo: &CpuTopology, cores: &[usize]) -> PinOutcome {
    // cpu_set_t is 1024 bits on every mainstream kernel.
    let mut mask = [0u64; 16];
    for &c in cores {
        if c < 1024 {
            mask[c / 64] |= 1u64 << (c % 64);
        }
    }
    if mask.iter().all(|&w| w == 0) {
        return PinOutcome::Unrestricted;
    }
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // pid 0 = the calling thread.
    let rc = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    if rc == 0 {
        PinOutcome::Pinned
    } else {
        PinOutcome::Failed
    }
}

#[cfg(target_os = "macos")]
fn pin_impl(topo: &CpuTopology, cores: &[usize]) -> PinOutcome {
    use std::ffi::c_int;
    // macOS ignores explicit cpu ids; express the intent as QoS class
    // (performance vs efficiency set) + an affinity tag per target
    // cluster so same-cluster threads are scheduled to share caches.
    const QOS_CLASS_USER_INTERACTIVE: u32 = 0x21;
    const QOS_CLASS_UTILITY: u32 = 0x11;
    const THREAD_AFFINITY_POLICY: c_int = 4;
    extern "C" {
        fn pthread_set_qos_class_self_np(qos_class: u32, relative_priority: c_int) -> c_int;
        fn mach_thread_self() -> u32;
        fn thread_policy_set(
            thread: u32,
            flavor: c_int,
            policy_info: *const c_int,
            count: u32,
        ) -> c_int;
    }
    let perf = topo.perf_cores();
    let on_perf = cores.iter().any(|c| perf.contains(c));
    let qos = if on_perf {
        QOS_CLASS_USER_INTERACTIVE
    } else {
        QOS_CLASS_UTILITY
    };
    let qos_rc = unsafe { pthread_set_qos_class_self_np(qos, 0) };
    // Tag = first target cluster + 1 (0 means "no affinity" to Mach).
    let tag: c_int = cores
        .first()
        .and_then(|&c| topo.cluster_of(c))
        .map(|i| i as c_int + 1)
        .unwrap_or(1);
    let policy_rc =
        unsafe { thread_policy_set(mach_thread_self(), THREAD_AFFINITY_POLICY, &tag, 1) };
    // Affinity tags are advisory (and rejected on Apple Silicon); QoS
    // succeeding is what counts.
    if qos_rc == 0 || policy_rc == 0 {
        PinOutcome::Pinned
    } else {
        PinOutcome::Failed
    }
}

#[cfg(not(any(target_os = "linux", target_os = "macos")))]
fn pin_impl(_topo: &CpuTopology, _cores: &[usize]) -> PinOutcome {
    PinOutcome::Unsupported
}

/// The [`ClusterKind`] a worker's core set predominantly targets — used
/// for `/status` rows and the macOS QoS mapping.
pub fn target_kind(topo: &CpuTopology, cores: &[usize]) -> ClusterKind {
    let perf = topo.perf_cores();
    if cores.iter().any(|c| perf.contains(c)) {
        ClusterKind::Performance
    } else {
        ClusterKind::Efficiency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_cli_spellings() {
        assert_eq!(
            PlacementPolicy::parse("perf"),
            Some(PlacementPolicy::PerfCoresFirst)
        );
        assert_eq!(
            PlacementPolicy::parse("Perf-Cores-First"),
            Some(PlacementPolicy::PerfCoresFirst)
        );
        assert_eq!(PlacementPolicy::parse("compact"), Some(PlacementPolicy::Compact));
        assert_eq!(PlacementPolicy::parse("spread"), Some(PlacementPolicy::Spread));
        assert_eq!(PlacementPolicy::parse("none"), Some(PlacementPolicy::None));
        assert_eq!(PlacementPolicy::parse("off"), Some(PlacementPolicy::None));
        assert_eq!(PlacementPolicy::parse("bogus"), None);
        for p in PlacementPolicy::all() {
            assert_eq!(PlacementPolicy::parse(p.as_str()), Some(p), "{p} roundtrips");
        }
    }

    #[test]
    fn perf_first_restricts_to_p_cores() {
        let topo = CpuTopology::apple_like();
        for w in 0..8 {
            let cores = core_set(PlacementPolicy::PerfCoresFirst, &topo, w, 8);
            assert_eq!(cores, vec![0, 1, 2, 3], "worker {w} gets the P set");
        }
        // Homogeneous topology: P set == all cores.
        let flat = CpuTopology::flat(4);
        assert_eq!(
            core_set(PlacementPolicy::PerfCoresFirst, &flat, 0, 2),
            vec![0, 1, 2, 3]
        );
    }

    #[test]
    fn compact_fills_clusters_densely() {
        let topo = CpuTopology::apple_like();
        let singles: Vec<usize> = (0..10)
            .map(|w| core_set(PlacementPolicy::Compact, &topo, w, 10)[0])
            .collect();
        // 4 P cores, then 4 E cores, then wrap.
        assert_eq!(singles, vec![0, 1, 2, 3, 4, 5, 6, 7, 0, 1]);
    }

    #[test]
    fn spread_alternates_clusters() {
        let topo = CpuTopology::apple_like();
        let singles: Vec<usize> = (0..6)
            .map(|w| core_set(PlacementPolicy::Spread, &topo, w, 6)[0])
            .collect();
        // P, E, P, E, ...
        assert_eq!(singles, vec![0, 4, 1, 5, 2, 6]);
    }

    #[test]
    fn none_is_all_cores() {
        let topo = CpuTopology::apple_like();
        assert_eq!(
            core_set(PlacementPolicy::None, &topo, 3, 4),
            vec![0, 1, 2, 3, 4, 5, 6, 7]
        );
    }

    #[test]
    fn pin_with_full_set_is_unrestricted() {
        let topo = CpuTopology::flat(2);
        assert_eq!(pin_current_thread(&topo, &[0, 1]), PinOutcome::Unrestricted);
        assert_eq!(pin_current_thread(&topo, &[]), PinOutcome::Unrestricted);
    }

    #[test]
    fn pin_to_one_core_reports_an_outcome() {
        // On Linux this really pins (then we restore); elsewhere it must
        // not pretend to.
        let topo = CpuTopology::host().clone();
        if topo.num_cores() < 2 {
            return;
        }
        let outcome = pin_current_thread(&topo, &[topo.perf_cores()[0]]);
        match outcome {
            PinOutcome::Pinned => {
                assert!(platform_supported());
                // Restore: widen back to every core (full set short-circuits
                // to Unrestricted, so call the impl via a near-full set).
                let all: Vec<usize> = (0..topo.num_cores()).collect();
                let _ = pin_impl(&topo, &all);
            }
            PinOutcome::Unsupported => assert!(!platform_supported()),
            PinOutcome::Failed | PinOutcome::Unrestricted => {}
        }
    }

    #[test]
    fn target_kind_tracks_cluster() {
        let topo = CpuTopology::apple_like();
        assert_eq!(target_kind(&topo, &[0]), ClusterKind::Performance);
        assert_eq!(target_kind(&topo, &[5]), ClusterKind::Efficiency);
        assert_eq!(target_kind(&topo, &[5, 1]), ClusterKind::Performance);
    }
}
