//! Minimal JSON encoder/decoder (no serde available offline).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used for model configs, the AOT artifact
//! manifest, server request/response bodies, and metrics dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- accessors -------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- encoding --------------------------------------------------------

    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Pretty-print with 2-space indentation (for configs on disk).
    pub fn encode_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, it) in items.iter().enumerate() {
                    indent(out, depth + 1);
                    it.write_pretty(out, depth + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    indent(out, depth + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    if i + 1 < map.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    // ---- decoding --------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bytes[self.pos..].starts_with(b"\\u") {
                                self.pos += 2;
                                let lo = self.hex4()?;
                                let combined =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(combined)
                            } else {
                                None
                            }
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: re-decode from the original slice.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.encode()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn encode_escapes() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(v.encode(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers_scientific() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("model", Json::str("ffn")),
            ("dims", Json::arr([Json::num(1024), Json::num(4096)])),
        ]);
        let pretty = v.encode_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
