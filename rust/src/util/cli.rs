//! Command-line argument parsing (no clap available offline).
//!
//! Supports `subcommand --flag value --switch positional` style invocations
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments: one optional subcommand, `--key value` options,
/// `--switch` booleans, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable); skips argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut it = argv.into_iter().skip(1).peekable();
        // First non-flag token is the subcommand.
        if let Some(tok) = it.peek() {
            if !tok.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--key=value` or `--key value` or bare switch.
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.opts.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn parse() -> Args {
        Self::parse_from(std::env::args())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    eprintln!("error: --{key} expects an integer, got '{v}'");
                    std::process::exit(2);
                })
            })
            .unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    eprintln!("error: --{key} expects a float, got '{v}'");
                    std::process::exit(2);
                })
            })
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    eprintln!("error: --{key} expects an integer, got '{v}'");
                    std::process::exit(2);
                })
            })
            .unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--ks 1024,2048,4096`.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse().unwrap_or_else(|_| {
                        eprintln!("error: --{key} expects comma-separated integers");
                        std::process::exit(2);
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(line: &str) -> Args {
        let argv = std::iter::once("prog".to_string())
            .chain(line.split_whitespace().map(|s| s.to_string()));
        Args::parse_from(argv)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --port 8080 --model cfg.json");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize("port", 0), 8080);
        assert_eq!(a.get("model"), Some("cfg.json"));
    }

    #[test]
    fn switches_and_equals() {
        let a = parse("bench --figure=fig6 --verbose");
        assert_eq!(a.get("figure"), Some("fig6"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn positionals() {
        let a = parse("quantize in.bin out.bin --sparsity 0.25");
        assert_eq!(a.positional, vec!["in.bin", "out.bin"]);
        assert!((a.f32("sparsity", 0.0) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.usize("port", 9000), 9000);
        assert_eq!(a.get_or("host", "127.0.0.1"), "127.0.0.1");
    }

    #[test]
    fn usize_list_parsing() {
        let a = parse("bench --ks 1,2,4");
        assert_eq!(a.usize_list("ks", &[9]), vec![1, 2, 4]);
        assert_eq!(a.usize_list("ms", &[9]), vec![9]);
    }

    #[test]
    fn no_subcommand_when_flag_first() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has("help"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("bench --alpha -0.5");
        assert_eq!(a.get("alpha"), Some("-0.5"));
    }
}
