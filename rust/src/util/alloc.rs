//! Aligned / hugepage-advised allocation for the big long-lived
//! buffers: activation-arena pairs and prepared sparse formats.
//!
//! Two independent levers, both best-effort and both invisible to the
//! math (placement and backing move bytes, never change them):
//!
//! - [`AlignedBuffer`]: a page-aligned `f32` region. `mmap` on Linux,
//!   `vm_allocate` on macOS, plain `Vec` everywhere else (and whenever
//!   the platform call fails). Page alignment makes the whole region
//!   eligible for transparent hugepages and keeps arena ping-pong
//!   halves from sharing a line.
//! - [`advise_hugepages_f32`]: `madvise(MADV_HUGEPAGE)` on the
//!   page-aligned interior of *any* existing allocation — legal on heap
//!   memory, so `Matrix`'s ordinary `Vec` backing benefits without an
//!   API change. THP collapses the range to 2 MiB pages in the
//!   background; returns whether the kernel accepted the hint.
//!
//! First-touch matters as much as backing: on NUMA/cluster parts, pages
//! are placed on first write, so [`first_touch_band`] lets the worker
//! that *owns* a row band be the one to fault its pages in (the arena
//! calls it from the placed pool at lease time).

const PAGE: usize = 4096;

/// Which backing an [`AlignedBuffer`] ended up with — surfaced in
/// `/status` so a silent fallback is still visible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backing {
    /// Linux `mmap` (anonymous, page-aligned).
    Mmap,
    /// macOS `vm_allocate` (page-aligned).
    VmAllocate,
    /// Portable `Vec<f32>` fallback.
    Vec,
}

impl Backing {
    pub fn as_str(&self) -> &'static str {
        match self {
            Backing::Mmap => "mmap",
            Backing::VmAllocate => "vm_allocate",
            Backing::Vec => "vec",
        }
    }
}

enum Storage {
    #[cfg_attr(not(any(target_os = "linux", target_os = "macos")), allow(dead_code))]
    Raw {
        ptr: *mut f32,
        bytes: usize,
        backing: Backing,
    },
    Vec(Vec<f32>),
}

/// A zero-initialized, page-aligned `f32` buffer with a portable
/// fallback. Dereferences to `[f32]`.
pub struct AlignedBuffer {
    storage: Storage,
    len: usize,
}

// The raw region is uniquely owned; f32s are Send + Sync.
unsafe impl Send for AlignedBuffer {}
unsafe impl Sync for AlignedBuffer {}

impl AlignedBuffer {
    /// Allocate `len` zeroed f32s, page-aligned when the platform
    /// cooperates. `mmap`/`vm_allocate` memory is zero-filled by the
    /// kernel; the Vec fallback zeroes explicitly.
    pub fn zeroed_f32(len: usize) -> AlignedBuffer {
        let bytes = len.saturating_mul(std::mem::size_of::<f32>());
        if len == 0 {
            return AlignedBuffer {
                storage: Storage::Vec(Vec::new()),
                len: 0,
            };
        }
        if let Some(storage) = raw_alloc(bytes) {
            return AlignedBuffer { storage, len };
        }
        AlignedBuffer {
            storage: Storage::Vec(vec![0.0; len]),
            len,
        }
    }

    /// Allocate and immediately request hugepage backing.
    pub fn zeroed_f32_hugepage(len: usize) -> AlignedBuffer {
        let mut buf = AlignedBuffer::zeroed_f32(len);
        let _ = advise_hugepages_f32(buf.as_mut_slice());
        buf
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Which allocator actually backed this buffer.
    pub fn backing(&self) -> Backing {
        match &self.storage {
            Storage::Raw { backing, .. } => *backing,
            Storage::Vec(_) => Backing::Vec,
        }
    }

    pub fn as_slice(&self) -> &[f32] {
        match &self.storage {
            Storage::Raw { ptr, .. } => unsafe { std::slice::from_raw_parts(*ptr, self.len) },
            Storage::Vec(v) => v,
        }
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        match &mut self.storage {
            Storage::Raw { ptr, .. } => unsafe { std::slice::from_raw_parts_mut(*ptr, self.len) },
            Storage::Vec(v) => v,
        }
    }
}

impl std::ops::Deref for AlignedBuffer {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl std::ops::DerefMut for AlignedBuffer {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.as_mut_slice()
    }
}

impl Drop for AlignedBuffer {
    fn drop(&mut self) {
        if let Storage::Raw { ptr, bytes, backing } = &self.storage {
            raw_free(*ptr, *bytes, *backing);
        }
    }
}

impl std::fmt::Debug for AlignedBuffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedBuffer")
            .field("len", &self.len)
            .field("backing", &self.backing().as_str())
            .finish()
    }
}

#[cfg(target_os = "linux")]
fn raw_alloc(bytes: usize) -> Option<Storage> {
    use std::ffi::c_void;
    const PROT_READ: i32 = 1;
    const PROT_WRITE: i32 = 2;
    const MAP_PRIVATE: i32 = 0x02;
    const MAP_ANONYMOUS: i32 = 0x20;
    extern "C" {
        fn mmap(
            addr: *mut c_void,
            length: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
    }
    let rounded = bytes.div_ceil(PAGE) * PAGE;
    let ptr = unsafe {
        mmap(
            std::ptr::null_mut(),
            rounded,
            PROT_READ | PROT_WRITE,
            MAP_PRIVATE | MAP_ANONYMOUS,
            -1,
            0,
        )
    };
    // MAP_FAILED is -1.
    if ptr.is_null() || ptr as isize == -1 {
        return None;
    }
    Some(Storage::Raw {
        ptr: ptr as *mut f32,
        bytes: rounded,
        backing: Backing::Mmap,
    })
}

#[cfg(target_os = "macos")]
fn raw_alloc(bytes: usize) -> Option<Storage> {
    extern "C" {
        fn mach_task_self() -> u32;
        fn vm_allocate(task: u32, address: *mut usize, size: usize, flags: i32) -> i32;
    }
    const VM_FLAGS_ANYWHERE: i32 = 0x0001;
    let rounded = bytes.div_ceil(PAGE) * PAGE;
    let mut addr: usize = 0;
    let kr = unsafe { vm_allocate(mach_task_self(), &mut addr, rounded, VM_FLAGS_ANYWHERE) };
    if kr != 0 || addr == 0 {
        return None;
    }
    Some(Storage::Raw {
        ptr: addr as *mut f32,
        bytes: rounded,
        backing: Backing::VmAllocate,
    })
}

#[cfg(not(any(target_os = "linux", target_os = "macos")))]
fn raw_alloc(_bytes: usize) -> Option<Storage> {
    None
}

#[cfg(target_os = "linux")]
fn raw_free(ptr: *mut f32, bytes: usize, _backing: Backing) {
    use std::ffi::c_void;
    extern "C" {
        fn munmap(addr: *mut c_void, length: usize) -> i32;
    }
    unsafe {
        munmap(ptr as *mut c_void, bytes);
    }
}

#[cfg(target_os = "macos")]
fn raw_free(ptr: *mut f32, bytes: usize, _backing: Backing) {
    extern "C" {
        fn mach_task_self() -> u32;
        fn vm_deallocate(task: u32, address: usize, size: usize) -> i32;
    }
    unsafe {
        vm_deallocate(mach_task_self(), ptr as usize, bytes);
    }
}

#[cfg(not(any(target_os = "linux", target_os = "macos")))]
fn raw_free(_ptr: *mut f32, _bytes: usize, _backing: Backing) {}

/// Ask the kernel to back the page-aligned interior of `data` with
/// transparent hugepages. Legal on any allocation (heap `Vec`s
/// included) — `madvise` only needs page-aligned *addresses*, and THP
/// collapse happens in the background. Returns `true` iff a non-empty
/// aligned range existed and the kernel accepted the hint; `false` is
/// the portable no-op (macOS superpages are not worth forcing for f32
/// streams; other platforms have no primitive).
pub fn advise_hugepages_f32(data: &mut [f32]) -> bool {
    if data.is_empty() {
        return false;
    }
    let start = data.as_ptr() as usize;
    let end = start + std::mem::size_of_val(data);
    let a_start = start.div_ceil(PAGE) * PAGE;
    let a_end = (end / PAGE) * PAGE;
    if a_end <= a_start {
        return false;
    }
    advise_impl(a_start, a_end - a_start)
}

#[cfg(target_os = "linux")]
fn advise_impl(addr: usize, len: usize) -> bool {
    use std::ffi::c_void;
    const MADV_HUGEPAGE: i32 = 14;
    extern "C" {
        fn madvise(addr: *mut c_void, length: usize, advice: i32) -> i32;
    }
    unsafe { madvise(addr as *mut c_void, len, MADV_HUGEPAGE) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn advise_impl(_addr: usize, _len: usize) -> bool {
    false
}

/// First-touch a row band of a row-major `rows × cols` buffer: write
/// one zero per page so the faulting thread's locality domain owns the
/// pages. Call from the worker that will consume the band.
pub fn first_touch_band(data: &mut [f32], cols: usize, row_start: usize, row_end: usize) {
    if cols == 0 {
        return;
    }
    let lo = (row_start * cols).min(data.len());
    let hi = (row_end * cols).min(data.len());
    let step = PAGE / std::mem::size_of::<f32>();
    let mut i = lo;
    while i < hi {
        data[i] = 0.0;
        i += step;
    }
    if hi > lo {
        data[hi - 1] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeroed_buffer_is_zero_and_sized() {
        let buf = AlignedBuffer::zeroed_f32(1000);
        assert_eq!(buf.len(), 1000);
        assert!(buf.iter().all(|&v| v == 0.0));
        // Raw backings must be page-aligned; the Vec fallback need not be.
        if buf.backing() != Backing::Vec {
            assert_eq!(buf.as_slice().as_ptr() as usize % PAGE, 0);
        }
        assert!(!buf.backing().as_str().is_empty());
    }

    #[test]
    fn buffer_is_writable_and_roundtrips() {
        let mut buf = AlignedBuffer::zeroed_f32(257);
        for (i, v) in buf.iter_mut().enumerate() {
            *v = i as f32;
        }
        assert_eq!(buf[0], 0.0);
        assert_eq!(buf[256], 256.0);
        let empty = AlignedBuffer::zeroed_f32(0);
        assert!(empty.is_empty());
        assert_eq!(empty.backing(), Backing::Vec);
    }

    #[test]
    fn hugepage_advise_never_corrupts() {
        let mut v = vec![7.0f32; 1 << 16];
        let accepted = advise_hugepages_f32(&mut v);
        // Hint or no hint, the data is untouched.
        assert!(v.iter().all(|&x| x == 7.0));
        if !cfg!(target_os = "linux") {
            assert!(!accepted, "non-Linux is a no-op");
        }
        // Tiny slices have no aligned interior.
        let mut tiny = [1.0f32; 4];
        assert!(!advise_hugepages_f32(&mut tiny));
        assert!(!advise_hugepages_f32(&mut []));
    }

    #[test]
    fn hugepage_buffer_constructor_zeroes() {
        let buf = AlignedBuffer::zeroed_f32_hugepage(4096 * 3);
        assert!(buf.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn first_touch_band_touches_every_page() {
        let cols = 300;
        let mut data = vec![f32::NAN; 10 * cols];
        first_touch_band(&mut data, cols, 2, 5);
        // The touched band's first element per page and its last element
        // are zeroed; nothing outside the band is written.
        assert_eq!(data[2 * cols], 0.0);
        assert_eq!(data[5 * cols - 1], 0.0);
        assert!(data[0].is_nan());
        assert!(data[6 * cols].is_nan());
        // Degenerate calls are safe.
        first_touch_band(&mut data, 0, 0, 10);
        first_touch_band(&mut data, cols, 8, 8);
        first_touch_band(&mut data, cols, 9, 99);
    }
}
