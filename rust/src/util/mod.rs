//! Substrates built in-repo (the environment is offline, so no external
//! crates beyond `xla`/`anyhow` are available): PRNG, JSON, CLI parsing,
//! a thread pool, and a miniature property-testing framework.

pub mod rng;
pub mod json;
pub mod cli;
pub mod threadpool;
pub mod quickcheck;
pub mod affinity;
pub mod alloc;

pub use affinity::{core_set, pin_current_thread, PinOutcome, PlacementPolicy};
pub use alloc::{advise_hugepages_f32, AlignedBuffer, Backing};
pub use rng::Rng;
pub use json::Json;
