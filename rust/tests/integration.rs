//! Cross-module integration tests: config → model → router → HTTP server →
//! load generator, model serialization round-trips through forward passes,
//! and the autotune/figure plumbing at smoke scale.

use std::sync::Arc;
use std::time::Duration;

use stgemm::bench::harness::BenchScale;
use stgemm::coordinator::server::{http_request, Server, ServerConfig};
use stgemm::coordinator::{BatchPolicy, Engine, LoadControlConfig, LoadGenerator, Router};
use stgemm::model::serialize::{from_bytes, to_bytes, LayerData};
use stgemm::model::{ModelConfig, TernaryLinear, TernaryMlp};
use stgemm::plan::{PlanHints, Planner};
use stgemm::tensor::Matrix;
use stgemm::util::json::Json;

fn demo_router(dims: &str, seed: u64) -> (Arc<Router>, usize, usize) {
    let cfg = ModelConfig::from_json(&format!(
        r#"{{"name":"demo","dims":{dims},"sparsity":0.25,"seed":{seed}}}"#
    ))
    .unwrap();
    let (d_in, d_out) = (cfg.d_in(), cfg.d_out());
    // Serving path: planner + plan cache pick kernels, no names pinned.
    let engine = Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
    let mut router = Router::new();
    router.register(
        engine,
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
        },
    );
    (Arc::new(router), d_in, d_out)
}

#[test]
fn full_stack_http_inference() {
    let (router, d_in, d_out) = demo_router("[32, 64, 16]", 5);
    let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();
    let input: Vec<String> = (0..d_in).map(|i| format!("{}", i as f32 * 0.01)).collect();
    let body = format!(r#"{{"model":"demo","input":[{}]}}"#, input.join(","));
    let (status, resp) = http_request(&server.local_addr, "POST", "/infer", &body).unwrap();
    assert_eq!(status, 200, "{resp}");
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.get("output").unwrap().as_arr().unwrap().len(), d_out);

    // HTTP result equals direct engine result.
    let x = Matrix::from_slice(
        1,
        d_in,
        &(0..d_in).map(|i| i as f32 * 0.01).collect::<Vec<_>>(),
    );
    let direct = router.engine("demo").unwrap().infer_matrix(&x).unwrap();
    for (j, item) in v.get("output").unwrap().as_arr().unwrap().iter().enumerate() {
        let got = item.as_f64().unwrap() as f32;
        assert!((got - direct[(0, j)]).abs() < 1e-4);
    }
}

#[test]
fn loadgen_through_http_server() {
    let (router, d_in, _) = demo_router("[16, 32, 8]", 9);
    let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();
    let gen = LoadGenerator {
        clients: 4,
        requests_per_client: 10,
        d_in,
        model: "demo".into(),
        seed: 3,
        request_timeout: Duration::from_secs(30),
    };
    let report = gen.run_http(server.local_addr);
    assert_eq!(report.total_requests, 40);
    assert_eq!(report.errors, 0);
    assert!(report.throughput_rps > 0.0);
}

#[test]
fn stw_serialization_preserves_forward_semantics() {
    // Build layers, serialize, rebuild a model from the decoded layers,
    // and check identical forward outputs.
    let cfg = ModelConfig::from_json(
        r#"{"name":"s","dims":[24,48,12],"sparsity":0.5,"seed":21}"#,
    )
    .unwrap();
    let original = TernaryMlp::from_config(&cfg).unwrap();

    // Reconstruct the same weights the config generates, then serialize.
    use stgemm::ternary::TernaryMatrix;
    use stgemm::util::rng::Rng;
    let mut layer_data = Vec::new();
    for i in 0..2 {
        let (k, n) = (cfg.dims[i], cfg.dims[i + 1]);
        let w = TernaryMatrix::random(k, n, cfg.sparsity, cfg.seed + i as u64);
        let mut rng = Rng::new(cfg.seed + i as u64 + 7777);
        let bias: Vec<f32> = (0..n).map(|_| rng.f32_range(-0.5, 0.5)).collect();
        layer_data.push(LayerData {
            weights: w,
            bias,
            scale: 1.0,
            prelu_alpha: (i == 0).then_some(cfg.prelu_alpha),
        });
    }
    let decoded = from_bytes(&to_bytes(&layer_data)).unwrap();
    // Decoded layers go back through the planner, as the artifact loader
    // does — kernel choice is the planning layer's job.
    let planner = Planner::new();
    let rebuilt_layers: Vec<TernaryLinear> = decoded
        .into_iter()
        .map(|l| {
            TernaryLinear::planned(
                &planner,
                &l.weights,
                l.bias,
                l.scale,
                l.prelu_alpha,
                &PlanHints::default(),
            )
            .unwrap()
        })
        .collect();
    let rebuilt = TernaryMlp::from_layers("s".into(), rebuilt_layers).unwrap();

    let x = Matrix::random(5, 24, 99);
    let a = original.forward(&x).unwrap();
    let b = rebuilt.forward(&x).unwrap();
    // Cross-kernel tolerance: the serving model's online race and the
    // rebuilt model's heuristic may legitimately pick different kernels.
    assert!(a.allclose(&b, 1e-4), "maxΔ {}", a.max_abs_diff(&b));
}

/// THE documented escape hatch: `TernaryLinear::new` pins an explicit
/// registry kernel, bypassing the tuning table, the heuristics and the
/// plan cache's online race. Benches and ablations rely on this staying
/// available; everything else should go through the planner.
#[test]
fn explicit_kernel_override_is_the_escape_hatch() {
    use stgemm::ternary::TernaryMatrix;
    let w = TernaryMatrix::random(64, 16, 0.25, 5);
    let bias = vec![0.1f32; 16];
    let pinned = TernaryLinear::new("base_tcsc", &w, bias.clone(), 1.0, None).unwrap();
    assert_eq!(pinned.kernel_name(), "base_tcsc");
    let planned = TernaryLinear::planned(
        &Planner::new(),
        &w,
        bias,
        1.0,
        None,
        &PlanHints::default(),
    )
    .unwrap();
    let x = Matrix::random(4, 64, 6);
    let mut yp = Matrix::zeros(4, 16);
    let mut ya = Matrix::zeros(4, 16);
    pinned.forward(&x, &mut yp).unwrap();
    planned.forward(&x, &mut ya).unwrap();
    assert!(yp.allclose(&ya, 1e-4), "override and planned path agree");
}

#[test]
fn autoscaled_serving_over_http() {
    let cfg = ModelConfig::from_json(
        r#"{"name":"demo","dims":[16,32,8],"sparsity":0.25,"seed":4}"#,
    )
    .unwrap();
    let engine = Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
    let mut router = Router::new();
    router.register_autoscaled(
        engine,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(300),
        },
        LoadControlConfig {
            max_batch: 16,
            max_threads: 4,
            adjust_every_batches: 2,
            ..LoadControlConfig::default()
        },
    );
    let router = Arc::new(router);
    let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();
    let gen = LoadGenerator {
        clients: 4,
        requests_per_client: 15,
        d_in: 16,
        model: "demo".into(),
        seed: 8,
        request_timeout: Duration::from_secs(30),
    };
    let report = gen.run_http(server.local_addr);
    assert_eq!(report.total_requests, 60);
    assert_eq!(report.errors, 0);
    // Mixed batch sizes hit the plan cache: after this traffic, the cache
    // holds a bounded set of plans and saw far more hits than misses.
    let cache = router
        .engine("demo")
        .unwrap()
        .plan_cache()
        .expect("config-built engine has a plan cache")
        .clone();
    let snap = cache.snapshot();
    assert!(snap.plans > 0, "plans were built");
    assert!(snap.hits > 0, "repeat buckets must hit the cache: {snap:?}");
    // Plans are bounded by layers × M-buckets × thread settings, never by
    // request count (the no-per-request-planning property).
    assert!(
        snap.plans <= 2 * 5 * 3,
        "plan count must stay bucket-bounded: {snap:?}"
    );
}

#[test]
fn figure_drivers_smoke_at_tiny_scale() {
    // The cheap analytic figure plus the headline driver in CI scale keeps
    // the figure plumbing honest inside `cargo test`.
    let t10 = stgemm::bench::figures::fig10_opint();
    assert!(!t10.rows.is_empty());
    let abl = stgemm::bench::figures::ablation_inverted(BenchScale::Ci);
    assert_eq!(abl.rows.len(), 4);
    for row in &abl.rows {
        let ratio: f64 = row[3].parse().unwrap();
        assert!(ratio > 0.0);
    }
}

#[test]
fn autotune_end_to_end() {
    use stgemm::autotune::grid::{best_point, unroll_grid_search};
    use stgemm::perf::timer::CycleTimer;
    let timer = CycleTimer::new(0, 1);
    let points = unroll_grid_search(8, 256, 64, 0.25, 3, &timer);
    let best = best_point(&points);
    assert!(best.flops_per_cycle > 0.0);
    // Unrolled kernels shouldn't be drastically slower than base.
    assert!(best.speedup_vs_base > 0.3, "speedup {}", best.speedup_vs_base);
}

#[test]
fn metrics_endpoint_reflects_traffic() {
    let (router, d_in, _) = demo_router("[8, 16, 4]", 2);
    let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();
    let body = format!(
        r#"{{"model":"demo","input":[{}]}}"#,
        vec!["0.2"; d_in].join(",")
    );
    for _ in 0..3 {
        let (s, _) = http_request(&server.local_addr, "POST", "/infer", &body).unwrap();
        assert_eq!(s, 200);
    }
    let (s, metrics) = http_request(&server.local_addr, "GET", "/metrics", "").unwrap();
    assert_eq!(s, 200);
    let v = Json::parse(&metrics).unwrap();
    let arr = v.as_arr().unwrap();
    assert_eq!(arr.len(), 1);
    let m = arr[0].get("metrics").unwrap();
    assert_eq!(m.get("responses").unwrap().as_f64(), Some(3.0));
}
