//! Cross-module integration tests: config → model → router → HTTP server →
//! load generator, model serialization round-trips through forward passes,
//! and the autotune/figure plumbing at smoke scale.

use std::sync::Arc;
use std::time::Duration;

use stgemm::bench::harness::BenchScale;
use stgemm::coordinator::server::{http_request, Server, ServerConfig};
use stgemm::coordinator::{BatchPolicy, Engine, LoadGenerator, Router};
use stgemm::model::serialize::{from_bytes, to_bytes, LayerData};
use stgemm::model::{ModelConfig, TernaryLinear, TernaryMlp};
use stgemm::tensor::Matrix;
use stgemm::util::json::Json;

fn demo_router(dims: &str, seed: u64) -> (Arc<Router>, usize, usize) {
    let cfg = ModelConfig::from_json(&format!(
        r#"{{"name":"demo","dims":{dims},"sparsity":0.25,"seed":{seed}}}"#
    ))
    .unwrap();
    let (d_in, d_out) = (cfg.d_in(), cfg.d_out());
    let engine = Engine::new("demo", TernaryMlp::from_config(&cfg).unwrap());
    let mut router = Router::new();
    router.register(
        engine,
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
        },
    );
    (Arc::new(router), d_in, d_out)
}

#[test]
fn full_stack_http_inference() {
    let (router, d_in, d_out) = demo_router("[32, 64, 16]", 5);
    let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();
    let input: Vec<String> = (0..d_in).map(|i| format!("{}", i as f32 * 0.01)).collect();
    let body = format!(r#"{{"model":"demo","input":[{}]}}"#, input.join(","));
    let (status, resp) = http_request(&server.local_addr, "POST", "/infer", &body).unwrap();
    assert_eq!(status, 200, "{resp}");
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.get("output").unwrap().as_arr().unwrap().len(), d_out);

    // HTTP result equals direct engine result.
    let x = Matrix::from_slice(
        1,
        d_in,
        &(0..d_in).map(|i| i as f32 * 0.01).collect::<Vec<_>>(),
    );
    let direct = router.engine("demo").unwrap().infer_matrix(&x).unwrap();
    for (j, item) in v.get("output").unwrap().as_arr().unwrap().iter().enumerate() {
        let got = item.as_f64().unwrap() as f32;
        assert!((got - direct[(0, j)]).abs() < 1e-4);
    }
}

#[test]
fn loadgen_through_http_server() {
    let (router, d_in, _) = demo_router("[16, 32, 8]", 9);
    let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();
    let gen = LoadGenerator {
        clients: 4,
        requests_per_client: 10,
        d_in,
        model: "demo".into(),
        seed: 3,
    };
    let report = gen.run_http(server.local_addr);
    assert_eq!(report.total_requests, 40);
    assert_eq!(report.errors, 0);
    assert!(report.throughput_rps > 0.0);
}

#[test]
fn stw_serialization_preserves_forward_semantics() {
    // Build layers, serialize, rebuild a model from the decoded layers,
    // and check identical forward outputs.
    let cfg = ModelConfig::from_json(
        r#"{"name":"s","dims":[24,48,12],"sparsity":0.5,"seed":21}"#,
    )
    .unwrap();
    let original = TernaryMlp::from_config(&cfg).unwrap();

    // Reconstruct the same weights the config generates, then serialize.
    use stgemm::ternary::TernaryMatrix;
    use stgemm::util::rng::Rng;
    let mut layer_data = Vec::new();
    for i in 0..2 {
        let (k, n) = (cfg.dims[i], cfg.dims[i + 1]);
        let w = TernaryMatrix::random(k, n, cfg.sparsity, cfg.seed + i as u64);
        let mut rng = Rng::new(cfg.seed + i as u64 + 7777);
        let bias: Vec<f32> = (0..n).map(|_| rng.f32_range(-0.5, 0.5)).collect();
        layer_data.push(LayerData {
            weights: w,
            bias,
            scale: 1.0,
            prelu_alpha: (i == 0).then_some(cfg.prelu_alpha),
        });
    }
    let decoded = from_bytes(&to_bytes(&layer_data)).unwrap();
    let rebuilt_layers: Vec<TernaryLinear> = decoded
        .into_iter()
        .map(|l| {
            TernaryLinear::new(
                "interleaved_blocked_tcsc",
                &l.weights,
                l.bias,
                l.scale,
                l.prelu_alpha,
            )
            .unwrap()
        })
        .collect();
    let rebuilt = TernaryMlp::from_layers("s".into(), rebuilt_layers).unwrap();

    let x = Matrix::random(5, 24, 99);
    let a = original.forward(&x);
    let b = rebuilt.forward(&x);
    assert!(a.allclose(&b, 1e-5), "maxΔ {}", a.max_abs_diff(&b));
}

#[test]
fn figure_drivers_smoke_at_tiny_scale() {
    // The cheap analytic figure plus the headline driver in CI scale keeps
    // the figure plumbing honest inside `cargo test`.
    let t10 = stgemm::bench::figures::fig10_opint();
    assert!(!t10.rows.is_empty());
    let abl = stgemm::bench::figures::ablation_inverted(BenchScale::Ci);
    assert_eq!(abl.rows.len(), 4);
    for row in &abl.rows {
        let ratio: f64 = row[3].parse().unwrap();
        assert!(ratio > 0.0);
    }
}

#[test]
fn autotune_end_to_end() {
    use stgemm::autotune::grid::{best_point, unroll_grid_search};
    use stgemm::perf::timer::CycleTimer;
    let timer = CycleTimer::new(0, 1);
    let points = unroll_grid_search(8, 256, 64, 0.25, 3, &timer);
    let best = best_point(&points);
    assert!(best.flops_per_cycle > 0.0);
    // Unrolled kernels shouldn't be drastically slower than base.
    assert!(best.speedup_vs_base > 0.3, "speedup {}", best.speedup_vs_base);
}

#[test]
fn metrics_endpoint_reflects_traffic() {
    let (router, d_in, _) = demo_router("[8, 16, 4]", 2);
    let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();
    let body = format!(
        r#"{{"model":"demo","input":[{}]}}"#,
        vec!["0.2"; d_in].join(",")
    );
    for _ in 0..3 {
        let (s, _) = http_request(&server.local_addr, "POST", "/infer", &body).unwrap();
        assert_eq!(s, 200);
    }
    let (s, metrics) = http_request(&server.local_addr, "GET", "/metrics", "").unwrap();
    assert_eq!(s, 200);
    let v = Json::parse(&metrics).unwrap();
    let arr = v.as_arr().unwrap();
    assert_eq!(arr.len(), 1);
    let m = arr[0].get("metrics").unwrap();
    assert_eq!(m.get("responses").unwrap().as_f64(), Some(3.0));
}
