//! Property tests over the coordinator: the batcher never loses,
//! duplicates or reorders requests; batched execution equals row-by-row
//! execution; the router answers everything under concurrency.

use std::sync::Arc;
use std::time::Duration;

use stgemm::coordinator::{BatchPolicy, DynamicBatcher, Engine, InferenceRequest, Router};
use stgemm::model::{ModelConfig, TernaryMlp};
use stgemm::tensor::Matrix;
use stgemm::util::quickcheck::{props, Gen};

fn engine(g: &mut Gen) -> Engine {
    let d_in = g.usize(2, 24);
    let d_h = g.usize(2, 32);
    let d_out = g.usize(1, 16);
    let cfg = ModelConfig::from_json(&format!(
        r#"{{"name":"p","dims":[{d_in},{d_h},{d_out}],"sparsity":0.25,"seed":{}}}"#,
        g.usize(0, 10_000)
    ))
    .unwrap();
    Engine::new("p", TernaryMlp::from_config(&cfg).unwrap())
}

#[test]
fn prop_batcher_no_loss_no_dup_fifo() {
    props("batcher conservation", 25, |g| {
        let max_batch = g.usize(1, 16);
        let n_req = g.usize(1, 64);
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(g.usize(1, 2000) as u64),
        });
        for i in 0..n_req {
            let (req, _rx) = InferenceRequest::new(i as u64, "m", vec![0.0]);
            b.submit(req).unwrap();
        }
        b.close();
        let mut ids = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= max_batch, "batch size bound");
            assert!(!batch.is_empty());
            ids.extend(batch.iter().map(|r| r.id));
        }
        // FIFO and conservation.
        assert_eq!(ids, (0..n_req as u64).collect::<Vec<_>>());
    });
}

#[test]
fn prop_batched_equals_rowwise() {
    props("batch == row-by-row", 15, |g| {
        let e = engine(g);
        let m = g.usize(1, 10);
        let x = Matrix::random(m, e.d_in(), g.seed());
        let batched = e.infer_matrix(&x).unwrap();
        for r in 0..m {
            let row = Matrix::from_slice(1, e.d_in(), x.row(r));
            let single = e.infer_matrix(&row).unwrap();
            for (a, b) in batched.row(r).iter().zip(single.as_slice()) {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())),
                    "row {r}: {a} vs {b}"
                );
            }
        }
    });
}

#[test]
fn prop_router_answers_everything() {
    props("router completeness", 8, |g| {
        let e = engine(g);
        let d_in = e.d_in();
        let d_out = e.d_out();
        let mut router = Router::new();
        router.register(
            e,
            BatchPolicy {
                max_batch: g.usize(1, 8),
                max_wait: Duration::from_micros(200),
            },
        );
        let router = Arc::new(router);
        let clients = g.usize(1, 6);
        let per_client = g.usize(1, 10);
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let router = Arc::clone(&router);
                std::thread::spawn(move || {
                    let mut got = 0;
                    for _ in 0..per_client {
                        let resp = router
                            .infer_blocking("p", vec![0.3; d_in], Duration::from_secs(10))
                            .expect("infer");
                        assert_eq!(resp.output.expect("ok").len(), d_out);
                        got += 1;
                    }
                    got
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, clients * per_client);
    });
}

#[test]
fn prop_metrics_counts_consistent() {
    props("metrics consistency", 10, |g| {
        let e = engine(g);
        let d_in = e.d_in();
        let n_batches = g.usize(1, 6);
        let mut expected_rows = 0;
        for _ in 0..n_batches {
            let rows = g.usize(1, 5);
            expected_rows += rows;
            let mut reqs = Vec::new();
            let mut rxs = Vec::new();
            for i in 0..rows {
                let (req, rx) = InferenceRequest::new(i as u64, "p", vec![0.1; d_in]);
                reqs.push(req);
                rxs.push(rx);
            }
            e.run_batch(reqs);
            for rx in rxs {
                rx.recv().unwrap().output.unwrap();
            }
        }
        use std::sync::atomic::Ordering;
        assert_eq!(e.metrics.responses.load(Ordering::Relaxed) as usize, expected_rows);
        assert_eq!(e.metrics.batches.load(Ordering::Relaxed) as usize, n_batches);
        assert_eq!(
            e.metrics.batched_rows.load(Ordering::Relaxed) as usize,
            expected_rows
        );
        assert_eq!(e.metrics.errors.load(Ordering::Relaxed), 0);
    });
}

#[test]
fn prop_bad_inputs_never_poison_batch() {
    props("failure isolation", 10, |g| {
        let e = engine(g);
        let d_in = e.d_in();
        let n = g.usize(2, 10);
        let bad_at = g.usize(0, n - 1);
        let mut reqs = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..n {
            let len = if i == bad_at { d_in + 1 } else { d_in };
            let (req, rx) = InferenceRequest::new(i as u64, "p", vec![0.0; len]);
            reqs.push(req);
            rxs.push(rx);
        }
        e.run_batch(reqs);
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            if i == bad_at {
                assert!(resp.output.is_err(), "bad request must error");
            } else {
                assert!(resp.output.is_ok(), "good request must survive");
            }
        }
    });
}
