//! Fleet-registry lifecycle linearizability tests: load → infer → unload
//! → reload under concurrent traffic, admission budgets, drain semantics,
//! and bitwise identity between registry-served outputs and a fresh
//! single-model engine.
//!
//! Determinism note: these tests pin `"kernel": "base_tcsc"` wherever
//! outputs are compared bitwise — without a pinned kernel the plan
//! cache's online top-2 race picks winners by timing, which is allowed to
//! differ between runs (outputs still agree, but the point here is exact
//! `f32::to_bits` equality along a known code path).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use stgemm::coordinator::{BatchPolicy, Engine, LoadOptions, ModelRegistry, ModelState};
use stgemm::model::ModelConfig;
use stgemm::plan::Planner;
use stgemm::tensor::Matrix;

fn cfg(name: &str, seed: u64) -> ModelConfig {
    ModelConfig::from_json(&format!(
        r#"{{"name":"{name}","dims":[16,32,8],"sparsity":0.5,"seed":{seed},
            "kernel":"base_tcsc"}}"#
    ))
    .unwrap()
}

fn registry() -> Arc<ModelRegistry> {
    Arc::new(ModelRegistry::with_thread_budget(
        Arc::new(Planner::new()),
        4,
    ))
}

fn quick_policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_micros(200),
    }
}

/// A policy that parks submitted requests in the queue: the bucket never
/// fills and the oldest-request deadline is far away, so queue depth is
/// exactly the number of outstanding submits until close() flushes them.
fn parked_policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 64,
        max_wait: Duration::from_secs(10),
    }
}

#[test]
fn lifecycle_load_infer_unload_reload_under_traffic() {
    let reg = registry();
    let c = cfg("churn", 11);
    reg.load(
        &c,
        LoadOptions {
            policy: quick_policy(),
            ..LoadOptions::default()
        },
    )
    .unwrap();

    let served = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicUsize::new(0));
    let mut clients = Vec::new();
    for t in 0..4 {
        let reg = Arc::clone(&reg);
        let (served, rejected, stop) =
            (Arc::clone(&served), Arc::clone(&rejected), Arc::clone(&stop));
        clients.push(std::thread::spawn(move || {
            let input: Vec<f32> = (0..16).map(|i| (i + t) as f32 * 0.1).collect();
            while stop.load(Ordering::Relaxed) == 0 {
                match reg.infer_blocking("churn", input.clone(), Duration::from_secs(5)) {
                    Ok(resp) => {
                        resp.output.expect("accepted request must compute");
                        served.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        // The only legal failures are rejections raised
                        // *before* a request is accepted; a timeout here
                        // would mean an accepted request was dropped.
                        let msg = e.to_string();
                        assert!(
                            msg.contains("draining")
                                || msg.contains("unknown model")
                                || msg.contains("shutting down"),
                            "unexpected failure mode: {msg}"
                        );
                        rejected.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }));
    }

    // Churn the lifecycle under live traffic: unload (drains in-flight
    // work) and immediately reload the same name.
    for _ in 0..3 {
        std::thread::sleep(Duration::from_millis(30));
        reg.unload("churn").unwrap();
        assert!(reg.get("churn").is_none(), "unload removes the name");
        reg.load(
            &c,
            LoadOptions {
                policy: quick_policy(),
                ..LoadOptions::default()
            },
        )
        .unwrap();
    }
    std::thread::sleep(Duration::from_millis(30));
    stop.store(1, Ordering::Relaxed);
    for h in clients {
        h.join().unwrap();
    }

    assert!(
        served.load(Ordering::Relaxed) > 0,
        "traffic must be served across reloads"
    );
    // The reloaded model still serves.
    let resp = reg
        .infer_blocking("churn", vec![0.25; 16], Duration::from_secs(5))
        .unwrap();
    assert_eq!(resp.output.unwrap().len(), 8);
    reg.shutdown();
}

#[test]
fn lifecycle_outputs_bitwise_identical_to_fresh_engine() {
    let c = cfg("bitwise", 7);
    let reg = registry();
    reg.load(
        &c,
        LoadOptions {
            policy: quick_policy(),
            warm: true,
            ..LoadOptions::default()
        },
    )
    .unwrap();
    assert_eq!(reg.get("bitwise").unwrap().state(), ModelState::Hot);

    // A fresh single-model engine on its own planner: the reference path.
    let fresh = Engine::from_config(&c, &Arc::new(Planner::new())).unwrap();
    let input: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) * 0.125).collect();
    let x = Matrix::from_slice(1, 16, &input);
    let want = fresh.infer_matrix(&x).unwrap();

    let check = |tag: &str| {
        let got = reg
            .infer_blocking("bitwise", input.clone(), Duration::from_secs(5))
            .unwrap()
            .output
            .unwrap();
        assert_eq!(got.len(), 8);
        for (j, &g) in got.iter().enumerate() {
            assert_eq!(
                g.to_bits(),
                want[(0, j)].to_bits(),
                "{tag}: output {j} not bitwise identical"
            );
        }
    };
    check("first load");

    // Unload releases the plans; a reload must rebuild to the same bits.
    reg.unload("bitwise").unwrap();
    reg.load(
        &c,
        LoadOptions {
            policy: quick_policy(),
            ..LoadOptions::default()
        },
    )
    .unwrap();
    check("after unload + reload");
    reg.shutdown();
}

#[test]
fn lifecycle_admission_budget_caps_queue() {
    let reg = registry();
    let c = cfg("tight", 3);
    reg.load(
        &c,
        LoadOptions {
            policy: parked_policy(),
            queue_budget: 1,
            ..LoadOptions::default()
        },
    )
    .unwrap();
    let handle = reg.get("tight").unwrap();

    // First submit parks in the queue (bucket of 64 never fills).
    let rx1 = reg.submit("tight", vec![0.5; 16]).unwrap();
    let deadline = Instant::now() + Duration::from_secs(2);
    while handle.queue_depth() < 1 {
        assert!(Instant::now() < deadline, "request never reached the queue");
        std::thread::yield_now();
    }

    // Second submit trips the budget: rejected, counted, nothing queued.
    let err = reg.submit("tight", vec![0.5; 16]).unwrap_err().to_string();
    assert!(err.contains("overloaded"), "got: {err}");
    assert_eq!(
        handle
            .engine()
            .metrics
            .admission_rejections
            .load(Ordering::Relaxed),
        1
    );
    assert_eq!(handle.queue_depth(), 1, "rejected submit must not queue");

    // Lifting the budget re-admits.
    handle.admission().set_budget(0);
    let rx2 = reg.submit("tight", vec![0.5; 16]).unwrap();

    // Unload flushes the parked queue: both accepted requests complete.
    reg.unload("tight").unwrap();
    for rx in [rx1, rx2] {
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.output.unwrap().len(), 8);
    }
}

#[test]
fn lifecycle_no_request_lost_on_unload() {
    let reg = registry();
    reg.load(
        &cfg("flush", 5),
        LoadOptions {
            policy: parked_policy(),
            ..LoadOptions::default()
        },
    )
    .unwrap();

    // Park a pile of accepted requests, then unload. Every accepted
    // request must receive a computed response — drain closes the batcher
    // but the batch loop flushes the queue before exiting.
    let rxs: Vec<_> = (0..10)
        .map(|i| reg.submit("flush", vec![i as f32 * 0.1; 16]).unwrap())
        .collect();
    reg.unload("flush").unwrap();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(5))
            .unwrap_or_else(|e| panic!("request {i} lost on unload: {e}"));
        assert_eq!(resp.output.unwrap().len(), 8, "request {i}");
    }
    // And the name is gone.
    let err = reg.submit("flush", vec![0.0; 16]).unwrap_err().to_string();
    assert!(err.contains("unknown model"), "got: {err}");
}

#[test]
fn lifecycle_draining_rejects_new_requests() {
    let reg = registry();
    reg.load(
        &cfg("drainer", 9),
        LoadOptions {
            policy: parked_policy(),
            ..LoadOptions::default()
        },
    )
    .unwrap();
    let parked = reg.submit("drainer", vec![0.1; 16]).unwrap();

    // Race submits against a concurrent unload. Linearizability contract:
    // every submit either (a) is accepted and receives a computed
    // response, or (b) fails with a lifecycle rejection — draining /
    // shutting down / unknown model. Nothing hangs, nothing is dropped.
    let reg_bg = Arc::clone(&reg);
    let unloader = std::thread::spawn(move || reg_bg.unload("drainer").unwrap());
    let mut accepted = Vec::new();
    let mut rejections = 0usize;
    loop {
        match reg.submit("drainer", vec![0.2; 16]) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                let msg = e.to_string();
                assert!(
                    msg.contains("draining")
                        || msg.contains("shutting down")
                        || msg.contains("unknown model"),
                    "unexpected failure mode: {msg}"
                );
                rejections += 1;
                if msg.contains("unknown model") {
                    break; // unload finished; the window is closed
                }
            }
        }
    }
    unloader.join().unwrap();
    assert!(rejections > 0, "the drain window must reject something");
    assert!(
        reg.submit("drainer", vec![0.3; 16]).is_err(),
        "no request may land on an unloaded model"
    );
    for rx in std::iter::once(parked).chain(accepted) {
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.output.unwrap().len(), 8);
    }
}
