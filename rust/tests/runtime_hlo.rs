//! Integration tests over the real AOT artifacts: PJRT compilation of the
//! JAX/Pallas-lowered HLO, probe-output verification, batch-bucket
//! padding, and the native-vs-XLA cross-check (the three-layer stack's
//! end-to-end correctness proof).
//!
//! Requires `make artifacts` to have run **and** the real `xla` bindings
//! (not the offline stub in `rust/vendor/xla`). When artifacts are absent
//! the tests skip with a message instead of failing, so the pure-Rust
//! tier-1 suite stays runnable offline.

use stgemm::coordinator::Engine;
use stgemm::model::{TernaryLinear, TernaryMlp};
use stgemm::plan::{PlanHints, Planner};
use stgemm::runtime::{Manifest, XlaExecutor};
use stgemm::tensor::Matrix;

fn manifest() -> Option<Manifest> {
    let dir = std::env::var("STGEMM_ARTIFACTS").unwrap_or_else(|_| {
        // Tests run from the crate root.
        "artifacts".to_string()
    });
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("[runtime_hlo] skipping (no artifacts: {e}); run `make artifacts`");
            None
        }
    }
}

/// Artifact weights flow through the planner (tuning table + paper
/// heuristics) like the serving path — no kernel names pinned here.
fn native_from_artifact(manifest: &Manifest, base: &str) -> TernaryMlp {
    let planner = Planner::new();
    let v0 = manifest.variants_of(base)[0];
    let mut layers = Vec::new();
    for (i, l) in v0.layers.iter().enumerate() {
        let w = v0.load_weights(&manifest.dir, i).expect("weights");
        let b = v0.load_bias(&manifest.dir, i).expect("bias");
        layers.push(
            TernaryLinear::planned(&planner, &w, b, 1.0, l.prelu_alpha, &PlanHints::default())
                .expect("layer"),
        );
    }
    TernaryMlp::from_layers(base.to_string(), layers).expect("mlp")
}

#[test]
fn manifest_lists_expected_models() {
    let Some(m) = manifest() else { return };
    for name in ["ffn_tiny_b1", "ffn_tiny_b8", "ffn_e2e_b1", "ffn_e2e_b8"] {
        assert!(m.model(name).is_some(), "missing artifact model {name}");
    }
}

#[test]
fn xla_executes_pallas_lowered_hlo_and_matches_probe() {
    let Some(m) = manifest() else { return };
    let xla = XlaExecutor::spawn(&m, "ffn_tiny").expect("spawn xla service");
    for v in m.variants_of("ffn_tiny") {
        let x = Matrix::from_slice(v.batch, v.d_in, &v.load_probe_x(&m.dir).unwrap());
        let want = Matrix::from_slice(v.batch, v.d_out, &v.load_probe_y(&m.dir).unwrap());
        let got = xla.run(&x).expect("xla run");
        assert!(
            got.allclose(&want, 1e-3),
            "{}: XLA output diverges from python probe by {}",
            v.name,
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn native_kernels_match_probe_outputs() {
    let Some(m) = manifest() else { return };
    let mlp = native_from_artifact(&m, "ffn_tiny");
    for v in m.variants_of("ffn_tiny") {
        let x = Matrix::from_slice(v.batch, v.d_in, &v.load_probe_x(&m.dir).unwrap());
        let want = Matrix::from_slice(v.batch, v.d_out, &v.load_probe_y(&m.dir).unwrap());
        let got = mlp.forward(&x).expect("native forward");
        assert!(
            got.allclose(&want, 1e-3),
            "{}: native output diverges by {}",
            v.name,
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn cross_backend_equivalence_on_random_inputs() {
    let Some(m) = manifest() else { return };
    let mlp = native_from_artifact(&m, "ffn_tiny");
    let xla = XlaExecutor::spawn(&m, "ffn_tiny").expect("xla");
    let engine = Engine::new("ffn_tiny", mlp).with_xla(xla);
    for seed in 0..5u64 {
        let x = Matrix::random(8, engine.d_in(), seed);
        let (_native, _xla, diff) = engine.cross_check(&x).expect("cross-check");
        assert!(diff < 1e-3, "seed {seed}: native vs xla maxΔ {diff}");
    }
}

#[test]
fn bucket_padding_slices_correct_rows() {
    let Some(m) = manifest() else { return };
    let xla = XlaExecutor::spawn(&m, "ffn_tiny").expect("xla");
    assert_eq!(xla.buckets(), &[1, 8]);
    // m=3 pads into the b8 executable; result must equal the first 3 rows
    // of running the full padded batch.
    let x = Matrix::random(3, xla.d_in, 77);
    let y = xla.run(&x).expect("run padded");
    assert_eq!(y.rows(), 3);
    let mut xp = Matrix::zeros(8, xla.d_in);
    for r in 0..3 {
        xp.row_mut(r).copy_from_slice(x.row(r));
    }
    let yf = xla.run(&xp).expect("run full");
    for r in 0..3 {
        for (a, b) in y.row(r).iter().zip(yf.row(r)) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}

#[test]
fn oversized_batch_is_rejected() {
    let Some(m) = manifest() else { return };
    let xla = XlaExecutor::spawn(&m, "ffn_tiny").expect("xla");
    let x = Matrix::random(9, xla.d_in, 1); // largest bucket is 8
    assert!(xla.run(&x).is_err());
}

#[test]
fn e2e_model_cross_check() {
    // The bigger e2e model (256→1024→256) through both backends.
    let Some(m) = manifest() else { return };
    let mlp = native_from_artifact(&m, "ffn_e2e");
    let xla = XlaExecutor::spawn(&m, "ffn_e2e").expect("xla");
    let engine = Engine::new("ffn_e2e", mlp).with_xla(xla);
    let x = Matrix::random(8, engine.d_in(), 42);
    let (_n, _x2, diff) = engine.cross_check(&x).expect("cross-check");
    assert!(diff < 1e-3, "e2e maxΔ {diff}");
}
