//! Planning-layer properties: every registry kernel runs correctly through
//! `GemmPlan` across batch sizes, sparsities and epilogue configurations;
//! steady-state execution is allocation-stable; parallel plans are bitwise
//! identical to sequential ones.

use stgemm::kernels::{dense_oracle, kernel_names, prelu_inplace, KernelParams};
use stgemm::perf::CpuCaps;
use stgemm::plan::{Epilogue, PlanHints, Planner};
use stgemm::tensor::Matrix;
use stgemm::ternary::TernaryMatrix;

/// A planner that can plan *every* registry kernel, including
/// capability-gated ones: gating is selection-time only and kernel
/// construction/execution is host-agnostic, so full-registry coverage
/// tests plan with a synthetic fully-capable host.
fn full_registry_planner() -> Planner {
    Planner::new().with_caps(CpuCaps::apple_like())
}

fn oracle_with(
    x: &Matrix,
    w: &TernaryMatrix,
    bias: &[f32],
    scale: f32,
    prelu: Option<f32>,
) -> Matrix {
    let mut y = dense_oracle(x, w, bias);
    if scale != 1.0 {
        for v in y.as_mut_slice() {
            *v *= scale;
        }
    }
    if let Some(alpha) = prelu {
        prelu_inplace(&mut y, alpha);
    }
    y
}

/// Satellite requirement: every registry kernel through `GemmPlan` matches
/// `dense_oracle` across M ∈ {1, 2, 7, 64}, sparsity ∈ {0.05, 0.25, 0.5},
/// with and without PReLU and scale.
#[test]
fn every_kernel_through_plan_matches_oracle() {
    let planner = full_registry_planner();
    let (k, n) = (96usize, 24usize);
    let bias: Vec<f32> = (0..n).map(|i| 0.07 * i as f32 - 0.5).collect();
    for &m in &[1usize, 2, 7, 64] {
        for &s in &[0.05f32, 0.25, 0.5] {
            let w = TernaryMatrix::random(k, n, s, 1000 + m as u64);
            let x = Matrix::random(m, k, 2000 + m as u64);
            for &(scale, prelu) in &[
                (1.0f32, None),
                (1.0, Some(0.25f32)),
                (0.5, None),
                (0.5, Some(0.25)),
            ] {
                let want = oracle_with(&x, &w, &bias, scale, prelu);
                for &name in kernel_names() {
                    let plan = planner
                        .plan(
                            &w,
                            KernelParams::default(),
                            Epilogue::new(bias.clone(), scale, prelu),
                            &PlanHints::with_kernel(name.parse().unwrap()),
                        )
                        .unwrap();
                    let mut y = Matrix::zeros(m, n);
                    plan.run(&x, &mut y).unwrap();
                    assert!(
                        y.allclose(&want, 2e-3),
                        "kernel {name} m={m} s={s} scale={scale} prelu={prelu:?} \
                         maxΔ {}",
                        y.max_abs_diff(&want)
                    );
                }
            }
        }
    }
}

/// Satellite requirement: steady-state `GemmPlan::run` performs no scratch
/// reallocation — capacity snapshot identical before/after repeated runs,
/// sequential and parallel, including smaller follow-up batches.
#[test]
fn steady_state_run_is_allocation_stable() {
    let planner = Planner::new();
    let (k, n, m) = (64usize, 32usize, 16usize);
    let w = TernaryMatrix::random(k, n, 0.25, 42);
    let x = Matrix::random(m, k, 43);
    for name in ["simd_vertical", "simd_horizontal", "interleaved_blocked_tcsc"] {
        for threads in [1usize, 4] {
            let hints = PlanHints {
                kernel: Some(name.parse().unwrap()),
                threads,
                expected_batch: m,
                ..Default::default()
            };
            let plan = planner
                .plan(
                    &w,
                    KernelParams::default(),
                    Epilogue::with_bias(vec![0.1; n]),
                    &hints,
                )
                .unwrap();
            let caps_before = plan.scratch_capacities();
            let mut y = Matrix::zeros(m, n);
            for _ in 0..8 {
                plan.run(&x, &mut y).unwrap();
            }
            assert_eq!(
                plan.scratch_capacities(),
                caps_before,
                "{name} threads={threads}: steady-state runs must not reallocate"
            );
            // A smaller batch reuses the same buffers.
            let x_small = Matrix::random(m / 2, k, 44);
            let mut y_small = Matrix::zeros(m / 2, n);
            plan.run(&x_small, &mut y_small).unwrap();
            assert_eq!(
                plan.scratch_capacities(),
                caps_before,
                "{name} threads={threads}: smaller batches must not reallocate"
            );
        }
    }
}

/// Parallel plans write disjoint Y row blocks in place and must produce
/// exactly the sequential bits for every kernel family.
#[test]
fn parallel_plan_is_bitwise_sequential() {
    let planner = full_registry_planner();
    let (k, n) = (80usize, 20usize);
    let w = TernaryMatrix::random(k, n, 0.25, 7);
    let bias: Vec<f32> = (0..n).map(|i| 0.02 * i as f32).collect();
    for &m in &[5usize, 13, 31] {
        let x = Matrix::random(m, k, 8 + m as u64);
        for &name in kernel_names() {
            let build = |threads: usize| {
                planner
                    .plan(
                        &w,
                        KernelParams::default(),
                        Epilogue::new(bias.clone(), 1.0, Some(0.25)),
                        &PlanHints {
                            kernel: Some(name.parse().unwrap()),
                            threads,
                            ..Default::default()
                        },
                    )
                    .unwrap()
            };
            let seq = build(1);
            let par = build(4);
            let mut y_seq = Matrix::zeros(m, n);
            let mut y_par = Matrix::zeros(m, n);
            seq.run(&x, &mut y_seq).unwrap();
            par.run(&x, &mut y_par).unwrap();
            assert_eq!(y_seq, y_par, "kernel {name} m={m}");
        }
    }
}

/// The planner consults the tuning table for model-build-time selection
/// and honors non-default interleave groups end to end.
#[test]
fn plan_respects_group_override() {
    let planner = Planner::new();
    let (k, n, m) = (96usize, 16usize, 6usize);
    let w = TernaryMatrix::random(k, n, 0.25, 9);
    let x = Matrix::random(m, k, 10);
    let bias = vec![0.05f32; n];
    let want = dense_oracle(&x, &w, &bias);
    for g in [1usize, 3, 4] {
        for name in ["interleaved_tcsc", "interleaved_blocked_tcsc", "simd_blocked_interleaved"] {
            let params = KernelParams {
                group: Some(g),
                ..Default::default()
            };
            let plan = planner
                .plan(
                    &w,
                    params,
                    Epilogue::with_bias(bias.clone()),
                    &PlanHints::with_kernel(name.parse().unwrap()),
                )
                .unwrap();
            let mut y = Matrix::zeros(m, n);
            plan.run(&x, &mut y).unwrap();
            assert!(y.allclose(&want, 1e-3), "{name} group={g}");
        }
    }
}
