//! Plan-cache properties (the adaptive-runtime acceptance bar):
//!
//! 1. Cached-plan output is **bitwise identical** to a freshly planned
//!    sequential run, across M buckets and thread counts — including when
//!    an M-aware tuning table picks a *different* kernel per bucket.
//! 2. A mixed-M request stream builds each (bucket, threads) plan once;
//!    after warmup, traffic only hits the cache.
//! 3. The online top-2 fallback races real batches, locks the winner into
//!    the shared tuning table **under the M-aware class**, and never races
//!    a tuned (class, bucket) again.
//! 4. A PR-2-era (K, sparsity)-keyed tuning JSON still loads and resolves
//!    for every batch size via the M-agnostic fallback.
//! 5. (PR 5) The wavefront-pipelined forward pass is **bitwise identical**
//!    to the sequential barrier path across M buckets × thread counts ×
//!    layer counts, including the M=0 and single-band edge cases, and
//!    steady-state pipelined serving performs zero activation allocation.

use std::sync::Arc;

use stgemm::autotune::{ShapeClass, TuneEntry, TuningTable};
use stgemm::kernels::{dense_oracle, KernelId, KernelParams};
use stgemm::model::{ModelConfig, TernaryMlp};
use stgemm::plan::{
    m_bucket, Epilogue, LayerSpec, PlanCache, PlanCacheConfig, PlanHints, Planner,
};
use stgemm::tensor::Matrix;
use stgemm::ternary::TernaryMatrix;

const K: usize = 96;
const N: usize = 24;

fn bias() -> Vec<f32> {
    (0..N).map(|i| 0.07 * i as f32 - 0.5).collect()
}

fn layer_spec(seed: u64, prelu: Option<f32>) -> LayerSpec {
    LayerSpec::new(
        TernaryMatrix::random(K, N, 0.25, seed),
        Epilogue::new(bias(), 1.0, prelu),
    )
}

/// Acceptance: cached-plan output equals a freshly planned sequential run,
/// bitwise, for every M bucket and thread count. Online racing is off so
/// the cache and the fresh planner make the same deterministic choice.
#[test]
fn cached_plan_is_bitwise_identical_to_fresh_sequential_plan() {
    let planner = Arc::new(Planner::new());
    let w = TernaryMatrix::random(K, N, 0.25, 7);
    for &threads in &[1usize, 2, 4, 8] {
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads,
                online_top2: false,
                race_reps: 1,
            },
        );
        let id = cache.register(layer_spec(7, Some(0.25))).unwrap();
        for &m in &[1usize, 2, 5, 7, 8, 9, 16, 33, 64] {
            let x = Matrix::random(m, K, 1000 + m as u64);
            let mut y_cached = Matrix::zeros(m, N);
            cache.run(id, &x, &mut y_cached).unwrap();

            // Fresh, sequential, planner-selected plan over the same data.
            let fresh = planner
                .plan(
                    &w,
                    KernelParams::default(),
                    Epilogue::new(bias(), 1.0, Some(0.25)),
                    &PlanHints::default(),
                )
                .unwrap();
            let mut y_fresh = Matrix::zeros(m, N);
            fresh.run(&x, &mut y_fresh).unwrap();
            assert_eq!(
                y_cached, y_fresh,
                "threads={threads} m={m} (bucket {}): cache diverged from \
                 fresh sequential plan",
                m_bucket(m)
            );
        }
    }
}

/// Tentpole acceptance: a synthetic table whose (K, s, M) winners differ
/// per bucket. Each M bucket's plan must use **its own** winner — the
/// M-aware entry when one exists, the M-agnostic fallback otherwise —
/// and every output must stay bitwise identical to a fresh sequential
/// plan pinned to that same kernel, at every thread count.
#[test]
fn per_m_table_winners_are_honored_per_bucket_and_stay_bitwise_identical() {
    let mut table = TuningTable::new();
    table.insert(
        ShapeClass::of(K, 0.25),
        TuneEntry::new(KernelId::InterleavedBlockedTcsc, 2.0),
    );
    table.insert(
        ShapeClass::of_m(K, 0.25, 1),
        TuneEntry::new(KernelId::UnrolledTcscK4M4, 3.0),
    );
    table.insert(
        ShapeClass::of_m(K, 0.25, 16),
        TuneEntry::new(KernelId::SimdVertical, 4.0),
    );
    let planner = Arc::new(Planner::with_table(table));
    let w = TernaryMatrix::random(K, N, 0.25, 51);
    for &threads in &[1usize, 2, 4] {
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads,
                online_top2: true, // fully tuned → must never race
                race_reps: 1,
            },
        );
        let id = cache
            .register(LayerSpec::new(w.clone(), Epilogue::new(bias(), 1.0, None)))
            .unwrap();
        // Bucket → expected winner (9 → bucket 16; 5 → bucket 8 →
        // fallback; 64 → untouched bucket → fallback).
        for &(m, want) in &[
            (1usize, KernelId::UnrolledTcscK4M4),
            (16, KernelId::SimdVertical),
            (9, KernelId::SimdVertical),
            (5, KernelId::InterleavedBlockedTcsc),
            (64, KernelId::InterleavedBlockedTcsc),
        ] {
            assert_eq!(cache.kernel_for(id, m), want, "m={m}");
            let plan = cache.plan_for(id, m).unwrap();
            assert_eq!(plan.kernel_name(), want.name(), "m={m}");
            let x = Matrix::random(m, K, 7000 + m as u64);
            let mut y_cached = Matrix::zeros(m, N);
            cache.run(id, &x, &mut y_cached).unwrap();
            // Fresh sequential plan pinned to the bucket's own winner.
            let fresh = planner
                .plan(
                    &w,
                    KernelParams::default(),
                    Epilogue::new(bias(), 1.0, None),
                    &PlanHints::with_kernel(want),
                )
                .unwrap();
            let mut y_fresh = Matrix::zeros(m, N);
            fresh.run(&x, &mut y_fresh).unwrap();
            assert_eq!(
                y_cached, y_fresh,
                "threads={threads} m={m}: M-aware winner diverged from its \
                 sequential twin"
            );
        }
        assert_eq!(cache.snapshot().races, 0, "tuned buckets must not race");
    }
}

/// Back-compat acceptance: the checked-in PR-2-era tuning JSON (M-agnostic
/// `k{K}_s{S}` keys only) still loads, and resolves for **every** batch
/// size via the (K, sparsity) fallback — so upgrading the binary never
/// orphans an existing table.
#[test]
fn pr2_era_tuning_json_resolves_via_m_agnostic_fallback() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/tuning_pr2.json"
    );
    let table = TuningTable::load(path).expect("PR-2 fixture must keep loading");
    assert_eq!(table.len(), 2);
    // K=96 buckets to 128, so the fixture's k128_s2500 entry covers it
    // at any batch size.
    for m in [1usize, 4, 8, 33, 1024] {
        let entry = table
            .lookup_m(K, 0.25, m)
            .expect("fallback must resolve every batch size");
        assert_eq!(entry.kernel, KernelId::UnrolledTcsc12, "m={m}");
    }
    assert_eq!(table.kernel_for(4096, 0.0625, 7), KernelId::UnrolledTcscK4M4);
    // The serving path honors the fixture: no race, fixture kernel used.
    let planner = Arc::new(Planner::with_table(table));
    let cache = PlanCache::new(
        Arc::clone(&planner),
        PlanCacheConfig {
            threads: 1,
            online_top2: true,
            race_reps: 1,
        },
    );
    let w = TernaryMatrix::random(K, N, 0.25, 61);
    let id = cache
        .register(LayerSpec::new(w.clone(), Epilogue::new(bias(), 1.0, None)))
        .unwrap();
    for m in [1usize, 8] {
        assert_eq!(cache.kernel_for(id, m), KernelId::UnrolledTcsc12);
        let x = Matrix::random(m, K, 8000 + m as u64);
        let y = cache.forward(id, &x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias()), 1e-3), "m={m}");
    }
    assert_eq!(
        cache.snapshot().races,
        0,
        "a fallback-covered class must never race"
    );
}

/// Even when the online race picks the kernel, the cached plan must stay
/// bitwise identical to a fresh *sequential* plan pinned to the same
/// kernel — thread fan-out never changes bits.
#[test]
fn raced_plan_is_bitwise_identical_to_its_sequential_twin() {
    let planner = Arc::new(Planner::new());
    let cache = PlanCache::new(
        Arc::clone(&planner),
        PlanCacheConfig {
            threads: 4,
            online_top2: true,
            race_reps: 1,
        },
    );
    let w = TernaryMatrix::random(K, N, 0.25, 13);
    let id = cache
        .register(LayerSpec::new(w.clone(), Epilogue::new(bias(), 1.0, None)))
        .unwrap();
    for &m in &[3usize, 8, 17] {
        let x = Matrix::random(m, K, 2000 + m as u64);
        let mut y_cached = Matrix::zeros(m, N);
        cache.run(id, &x, &mut y_cached).unwrap();
        // The race recorded this bucket's winner; a sequential plan pinned
        // to it must agree bitwise.
        let winner = planner
            .lookup_entry(K, 0.25, m)
            .expect("race must record a winner for the bucket")
            .kernel;
        let fresh = planner
            .plan(
                &w,
                KernelParams::default(),
                Epilogue::new(bias(), 1.0, None),
                &PlanHints::with_kernel(winner),
            )
            .unwrap();
        let mut y_fresh = Matrix::zeros(m, N);
        fresh.run(&x, &mut y_fresh).unwrap();
        assert_eq!(y_cached, y_fresh, "m={m} winner={winner}");
    }
}

/// Acceptance: a mixed-M stream constructs no plans after warmup — every
/// post-warmup request is a cache hit, and results stay correct.
#[test]
fn mixed_m_stream_hits_cache_after_warmup() {
    let planner = Arc::new(Planner::new());
    let cache = PlanCache::new(
        Arc::clone(&planner),
        PlanCacheConfig {
            threads: 2,
            online_top2: true,
            race_reps: 1,
        },
    );
    let w = TernaryMatrix::random(K, N, 0.25, 21);
    let id = cache
        .register(LayerSpec::new(w.clone(), Epilogue::new(bias(), 1.0, None)))
        .unwrap();
    let stream = [1usize, 4, 8, 2, 16, 7, 3, 8, 1, 5, 9, 16];
    // Warmup pass: first sighting of each bucket builds (and races it).
    for (i, &m) in stream.iter().enumerate() {
        let x = Matrix::random(m, K, 3000 + i as u64);
        let y = cache.forward(id, &x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias()), 1e-3), "m={m}");
    }
    let warm = cache.snapshot();
    let distinct_buckets = {
        let mut b: Vec<usize> = stream.iter().map(|&m| m_bucket(m)).collect();
        b.sort_unstable();
        b.dedup();
        b.len()
    };
    assert_eq!(warm.plans, distinct_buckets);
    assert_eq!(warm.misses, distinct_buckets as u64);
    // Per-bucket racing: every bucket raced exactly once during warmup.
    assert_eq!(warm.races, distinct_buckets as u64);
    // Steady state: identical stream, zero plan construction.
    for (i, &m) in stream.iter().enumerate() {
        let x = Matrix::random(m, K, 4000 + i as u64);
        cache.forward(id, &x).unwrap();
    }
    let hot = cache.snapshot();
    assert_eq!(hot.misses, warm.misses, "no per-request plan construction");
    assert_eq!(hot.plans, warm.plans);
    assert_eq!(hot.races, warm.races, "tuned buckets never race again");
    assert_eq!(hot.hits, warm.hits + stream.len() as u64);
}

/// The online race records exactly one winner per (class, bucket) and the
/// entry is one of the two paper candidates for that batch regime.
#[test]
fn online_race_is_once_per_class_bucket_and_paper_sane() {
    let planner = Arc::new(Planner::new());
    let cache = PlanCache::new(
        Arc::clone(&planner),
        PlanCacheConfig {
            threads: 1,
            online_top2: true,
            race_reps: 1,
        },
    );
    // Two layers in the same (K, sparsity) class.
    let a = cache.register(layer_spec(31, None)).unwrap();
    let b = cache
        .register(LayerSpec::new(
            TernaryMatrix::random(K, 8, 0.25, 32),
            Epilogue::with_bias(vec![0.0; 8]),
        ))
        .unwrap();
    assert!(planner.lookup_entry(K, 0.25, 8).is_none());
    let x = Matrix::random(8, K, 5000);
    cache.forward(a, &x).unwrap();
    let snap = cache.snapshot();
    assert_eq!(snap.races, 1);
    let entry = planner.lookup_entry(K, 0.25, 8).expect("winner recorded");
    let candidates = stgemm::plan::heuristic_top2(K, 0.25, 8, false);
    assert!(
        candidates.contains(&entry.kernel),
        "winner '{}' must be a top-2 candidate {:?}",
        entry.kernel,
        candidates
    );
    // The race was recorded under the M-aware class only: other buckets
    // of the same (K, sparsity) stay open for their own race.
    assert!(
        planner.lookup_entry(K, 0.25, 1).is_none(),
        "bucket 8's race must not settle bucket 1"
    );
    // Second layer of the class, same bucket: table hit, no second race.
    cache.forward(b, &x).unwrap();
    assert_eq!(cache.snapshot().races, 1);
}

/// Explicit kernel overrides bypass table and race — the documented
/// escape hatch survives the cache refactor.
#[test]
fn explicit_override_bypasses_race_and_table() {
    let planner = Arc::new(Planner::new());
    let cache = PlanCache::new(
        Arc::clone(&planner),
        PlanCacheConfig {
            threads: 1,
            online_top2: true,
            race_reps: 1,
        },
    );
    let w = TernaryMatrix::random(K, N, 0.25, 41);
    let mut spec = LayerSpec::new(w.clone(), Epilogue::new(bias(), 1.0, None));
    spec.kernel = Some(KernelId::BaseTcsc);
    let id = cache.register(spec).unwrap();
    let x = Matrix::random(8, K, 6000);
    let y = cache.forward(id, &x).unwrap();
    assert!(y.allclose(&dense_oracle(&x, &w, &bias()), 1e-3));
    assert_eq!(cache.snapshot().races, 0, "override must not race");
    assert!(planner.lookup_entry(K, 0.25, 8).is_none());
    assert_eq!(cache.kernel_for(id, 8), KernelId::BaseTcsc);
}

/// PR-5 tentpole acceptance: the wavefront-pipelined forward pass is
/// bitwise identical to the sequential barrier path across M buckets ×
/// thread counts (1–4) × layer counts (1–4) — including the M=0-rows edge
/// case and batches small enough to produce a single band per layer.
/// Kernel pinned so both paths deterministically execute the same plan.
#[test]
fn pipelined_forward_is_bitwise_identical_to_barrier_path() {
    let dims_by_layers: [&[usize]; 5] = [
        &[48, 16],
        &[48, 32, 16],
        &[48, 32, 24, 16],
        &[48, 32, 24, 20, 16],
        // Same-parity width mismatches (8 → 64 growing, 16 → 4 shrinking):
        // the ping-pong anti-dependency regression case.
        &[48, 8, 16, 64, 4, 16],
    ];
    for dims in &dims_by_layers {
        for threads in 1usize..=4 {
            let cfg = ModelConfig::from_json(&format!(
                r#"{{"name":"p","dims":{dims:?},"sparsity":0.25,"seed":9,
                    "prelu_alpha":0.25,"kernel":"interleaved_blocked_tcsc",
                    "threads":{threads}}}"#
            ))
            .unwrap();
            let mlp = TernaryMlp::from_config(&cfg).unwrap();
            // m=0: empty batch; m=1/3: a single band per layer.
            for &m in &[0usize, 1, 3, 8, 13, 33] {
                let x = Matrix::random(m, 48, 100 + m as u64);
                mlp.set_pipeline(true);
                let wave = mlp.forward(&x).unwrap();
                mlp.set_pipeline(false);
                let barrier = mlp.forward(&x).unwrap();
                assert_eq!(
                    wave, barrier,
                    "layers={} threads={threads} m={m}: wavefront diverged \
                     from the barrier path",
                    dims.len() - 1
                );
            }
        }
    }
}

/// Same identity with planner-selected kernels: the online races settle
/// each (class, bucket) into the shared table first (through the barrier
/// fallback), and the pipeline compiled afterwards must pick — and stay
/// bitwise identical to — exactly those winners.
#[test]
fn pipelined_auto_kernels_stay_bitwise_identical_after_races() {
    let planner = Arc::new(Planner::new());
    let cfg = ModelConfig::from_json(
        r#"{"name":"p","dims":[48,32,16],"sparsity":0.25,"seed":13,
            "prelu_alpha":0.25,"threads":4}"#,
    )
    .unwrap();
    let mlp = TernaryMlp::planned(&cfg, &planner).unwrap();
    for &m in &[1usize, 8, 16] {
        // First pass races (barrier fallback), second runs the pipeline.
        mlp.forward(&Matrix::random(m, 48, 200 + m as u64)).unwrap();
        let x = Matrix::random(m, 48, 300 + m as u64);
        mlp.set_pipeline(true);
        let wave = mlp.forward(&x).unwrap();
        mlp.set_pipeline(false);
        let barrier = mlp.forward(&x).unwrap();
        mlp.set_pipeline(true);
        assert_eq!(wave, barrier, "m={m}");
    }
    let cache = mlp.plan_cache().expect("config-built model");
    let snap = cache.snapshot();
    assert!(snap.races > 0, "untuned classes must have raced");
    assert!(snap.pipeline_plans > 0, "settled buckets must have pipelined");
}

/// Zero-allocation acceptance: after plan-cache warmup, steady-state
/// pipelined serving checks every activation buffer out of the arena —
/// the allocation counter freezes while the reuse counter climbs.
#[test]
fn steady_state_pipeline_has_zero_activation_allocations() {
    let cfg = ModelConfig::from_json(
        r#"{"name":"p","dims":[48,32,16],"sparsity":0.25,"seed":17,
            "kernel":"interleaved_blocked_tcsc","threads":2}"#,
    )
    .unwrap();
    let mlp = TernaryMlp::from_config(&cfg).unwrap();
    let cache = mlp.plan_cache().expect("config-built model");
    let stream = [1usize, 4, 8, 2, 16, 7, 3, 8];
    for (i, &m) in stream.iter().enumerate() {
        mlp.forward(&Matrix::random(m, 48, 400 + i as u64)).unwrap();
    }
    let warm = cache.arena_stats();
    assert!(warm.allocations > 0);
    for round in 0..3u64 {
        for (i, &m) in stream.iter().enumerate() {
            mlp.forward(&Matrix::random(m, 48, 500 + 20 * round + i as u64))
                .unwrap();
        }
    }
    let hot = cache.arena_stats();
    assert_eq!(
        hot.allocations, warm.allocations,
        "steady state must allocate no activation buffers"
    );
    assert_eq!(
        hot.reuses,
        warm.reuses + 3 * stream.len() as u64,
        "every steady-state forward reuses an arena pair"
    );
}

/// Regression: batches past the M-bucket cap (1024) must keep working on
/// every path — the arena leases exact-size buffer pairs there, and the
/// pipelined entry point falls back to the barrier path (whose bucketed
/// plans and pipelines stop covering `m`).
#[test]
fn batches_beyond_the_bucket_cap_still_forward() {
    let cfg = ModelConfig::from_json(
        r#"{"name":"big","dims":[8,16,4],"sparsity":0.25,"seed":23,
            "kernel":"base_tcsc","threads":2}"#,
    )
    .unwrap();
    let mlp = TernaryMlp::from_config(&cfg).unwrap();
    let m = stgemm::plan::MAX_M_BUCKET + 77;
    let x = Matrix::random(m, 8, 9);
    let wave = mlp.forward(&x).unwrap();
    assert_eq!((wave.rows(), wave.cols()), (m, 4));
    mlp.set_pipeline(false);
    let barrier = mlp.forward(&x).unwrap();
    assert_eq!(wave, barrier, "cap-overflow fallback must stay bitwise");
}
