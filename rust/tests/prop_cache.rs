//! Plan-cache properties (the adaptive-runtime acceptance bar):
//!
//! 1. Cached-plan output is **bitwise identical** to a freshly planned
//!    sequential run, across M buckets and thread counts.
//! 2. A mixed-M request stream builds each (bucket, threads) plan once;
//!    after warmup, traffic only hits the cache.
//! 3. The online top-2 fallback races real batches, locks the winner into
//!    the shared tuning table, and never races a tuned class again.

use std::sync::Arc;

use stgemm::kernels::{dense_oracle, KernelParams};
use stgemm::plan::{
    m_bucket, Epilogue, LayerSpec, PlanCache, PlanCacheConfig, PlanHints, Planner,
};
use stgemm::tensor::Matrix;
use stgemm::ternary::TernaryMatrix;

const K: usize = 96;
const N: usize = 24;

fn bias() -> Vec<f32> {
    (0..N).map(|i| 0.07 * i as f32 - 0.5).collect()
}

fn layer_spec(seed: u64, prelu: Option<f32>) -> LayerSpec {
    LayerSpec::new(
        TernaryMatrix::random(K, N, 0.25, seed),
        Epilogue::new(bias(), 1.0, prelu),
    )
}

/// Acceptance: cached-plan output equals a freshly planned sequential run,
/// bitwise, for every M bucket and thread count. Online racing is off so
/// the cache and the fresh planner make the same deterministic choice.
#[test]
fn cached_plan_is_bitwise_identical_to_fresh_sequential_plan() {
    let planner = Arc::new(Planner::new());
    let w = TernaryMatrix::random(K, N, 0.25, 7);
    for &threads in &[1usize, 2, 4, 8] {
        let cache = PlanCache::new(
            Arc::clone(&planner),
            PlanCacheConfig {
                threads,
                online_top2: false,
                race_reps: 1,
            },
        );
        let id = cache.register(layer_spec(7, Some(0.25))).unwrap();
        for &m in &[1usize, 2, 5, 7, 8, 9, 16, 33, 64] {
            let x = Matrix::random(m, K, 1000 + m as u64);
            let mut y_cached = Matrix::zeros(m, N);
            cache.run(id, &x, &mut y_cached).unwrap();

            // Fresh, sequential, planner-selected plan over the same data.
            let fresh = planner
                .plan(
                    &w,
                    KernelParams::default(),
                    Epilogue::new(bias(), 1.0, Some(0.25)),
                    &PlanHints::default(),
                )
                .unwrap();
            let mut y_fresh = Matrix::zeros(m, N);
            fresh.run(&x, &mut y_fresh);
            assert_eq!(
                y_cached, y_fresh,
                "threads={threads} m={m} (bucket {}): cache diverged from \
                 fresh sequential plan",
                m_bucket(m)
            );
        }
    }
}

/// Even when the online race picks the kernel, the cached plan must stay
/// bitwise identical to a fresh *sequential* plan pinned to the same
/// kernel — thread fan-out never changes bits.
#[test]
fn raced_plan_is_bitwise_identical_to_its_sequential_twin() {
    let planner = Arc::new(Planner::new());
    let cache = PlanCache::new(
        Arc::clone(&planner),
        PlanCacheConfig {
            threads: 4,
            online_top2: true,
            race_reps: 1,
        },
    );
    let w = TernaryMatrix::random(K, N, 0.25, 13);
    let id = cache
        .register(LayerSpec::new(w.clone(), Epilogue::new(bias(), 1.0, None)))
        .unwrap();
    for &m in &[3usize, 8, 17] {
        let x = Matrix::random(m, K, 2000 + m as u64);
        let mut y_cached = Matrix::zeros(m, N);
        cache.run(id, &x, &mut y_cached).unwrap();
        // The race recorded a winner; a sequential plan now selects it too.
        let winner = planner
            .lookup_entry(K, 0.25)
            .expect("race must record a winner")
            .kernel;
        let fresh = planner
            .plan(
                &w,
                KernelParams::default(),
                Epilogue::new(bias(), 1.0, None),
                &PlanHints::with_kernel(&winner),
            )
            .unwrap();
        let mut y_fresh = Matrix::zeros(m, N);
        fresh.run(&x, &mut y_fresh);
        assert_eq!(y_cached, y_fresh, "m={m} winner={winner}");
    }
}

/// Acceptance: a mixed-M stream constructs no plans after warmup — every
/// post-warmup request is a cache hit, and results stay correct.
#[test]
fn mixed_m_stream_hits_cache_after_warmup() {
    let planner = Arc::new(Planner::new());
    let cache = PlanCache::new(
        Arc::clone(&planner),
        PlanCacheConfig {
            threads: 2,
            online_top2: true,
            race_reps: 1,
        },
    );
    let w = TernaryMatrix::random(K, N, 0.25, 21);
    let id = cache
        .register(LayerSpec::new(w.clone(), Epilogue::new(bias(), 1.0, None)))
        .unwrap();
    let stream = [1usize, 4, 8, 2, 16, 7, 3, 8, 1, 5, 9, 16];
    // Warmup pass: first sighting of each bucket builds (and may race).
    for (i, &m) in stream.iter().enumerate() {
        let x = Matrix::random(m, K, 3000 + i as u64);
        let y = cache.forward(id, &x).unwrap();
        assert!(y.allclose(&dense_oracle(&x, &w, &bias()), 1e-3), "m={m}");
    }
    let warm = cache.snapshot();
    let distinct_buckets = {
        let mut b: Vec<usize> = stream.iter().map(|&m| m_bucket(m)).collect();
        b.sort_unstable();
        b.dedup();
        b.len()
    };
    assert_eq!(warm.plans, distinct_buckets);
    assert_eq!(warm.misses, distinct_buckets as u64);
    // Steady state: identical stream, zero plan construction.
    for (i, &m) in stream.iter().enumerate() {
        let x = Matrix::random(m, K, 4000 + i as u64);
        cache.forward(id, &x).unwrap();
    }
    let hot = cache.snapshot();
    assert_eq!(hot.misses, warm.misses, "no per-request plan construction");
    assert_eq!(hot.plans, warm.plans);
    assert_eq!(hot.races, warm.races, "tuned classes never race again");
    assert_eq!(hot.hits, warm.hits + stream.len() as u64);
}

/// The online race records exactly one winner per class and the entry is
/// one of the two paper candidates.
#[test]
fn online_race_is_once_per_class_and_paper_sane() {
    let planner = Arc::new(Planner::new());
    let cache = PlanCache::new(
        Arc::clone(&planner),
        PlanCacheConfig {
            threads: 1,
            online_top2: true,
            race_reps: 1,
        },
    );
    // Two layers in the same (K, sparsity) class.
    let a = cache.register(layer_spec(31, None)).unwrap();
    let b = cache
        .register(LayerSpec::new(
            TernaryMatrix::random(K, 8, 0.25, 32),
            Epilogue::with_bias(vec![0.0; 8]),
        ))
        .unwrap();
    assert!(planner.lookup_entry(K, 0.25).is_none());
    let x = Matrix::random(8, K, 5000);
    cache.forward(a, &x).unwrap();
    let snap = cache.snapshot();
    assert_eq!(snap.races, 1);
    let entry = planner.lookup_entry(K, 0.25).expect("winner recorded");
    let candidates = stgemm::plan::heuristic_top2(K, 0.25, false);
    assert!(
        candidates.contains(&entry.kernel.as_str()),
        "winner '{}' must be a top-2 candidate {:?}",
        entry.kernel,
        candidates
    );
    // Second layer of the class: table hit, no second race.
    cache.forward(b, &x).unwrap();
    assert_eq!(cache.snapshot().races, 1);
}

/// Explicit kernel overrides bypass table and race — the documented
/// escape hatch survives the cache refactor.
#[test]
fn explicit_override_bypasses_race_and_table() {
    let planner = Arc::new(Planner::new());
    let cache = PlanCache::new(
        Arc::clone(&planner),
        PlanCacheConfig {
            threads: 1,
            online_top2: true,
            race_reps: 1,
        },
    );
    let w = TernaryMatrix::random(K, N, 0.25, 41);
    let mut spec = LayerSpec::new(w.clone(), Epilogue::new(bias(), 1.0, None));
    spec.kernel = Some("base_tcsc".into());
    let id = cache.register(spec).unwrap();
    let x = Matrix::random(8, K, 6000);
    let y = cache.forward(id, &x).unwrap();
    assert!(y.allclose(&dense_oracle(&x, &w, &bias()), 1e-3));
    assert_eq!(cache.snapshot().races, 0, "override must not race");
    assert!(planner.lookup_entry(K, 0.25).is_none());
    assert_eq!(cache.kernel_for(id, 8), "base_tcsc");
}
