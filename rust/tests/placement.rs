//! PR 10 property tests: topology-aware worker placement.
//!
//! The standing invariant is that **placement moves work, never changes
//! it**: whatever cores the pool's workers pin to (or fail to pin to —
//! placement is best-effort everywhere), forward and decode outputs stay
//! bitwise-identical (`f32::to_bits`) to the sequential reference.
//! Alongside that, the checked-in sysfs/sysctl fixture snapshots pin the
//! topology classifier's behavior on the three shapes that matter: an
//! M1-like 4P+4E SoC, a flat x86 server (no `cpu_capacity`, private L2 —
//! must NOT shatter into singleton clusters), and a single-core host.

use std::sync::Arc;

use stgemm::model::{ModelConfig, TernaryMlp};
use stgemm::perf::{ClusterKind, CpuTopology};
use stgemm::plan::Planner;
use stgemm::tensor::Matrix;
use stgemm::util::{core_set, PlacementPolicy};

const M1_SYSFS: &str = include_str!("fixtures/topology/m1_4p4e.sysfs");
const FLAT_SYSFS: &str = include_str!("fixtures/topology/flat_x86.sysfs");
const SINGLE_SYSFS: &str = include_str!("fixtures/topology/single_core.sysfs");
const M1_SYSCTL: &str = include_str!("fixtures/topology/m1.sysctl");

fn topo_from_sysfs(text: &str) -> CpuTopology {
    CpuTopology::from_probes(CpuTopology::parse_sysfs_snapshot(text).expect("fixture parses"))
}

#[test]
fn placement_fixture_m1_sysfs_classifies_4p_plus_4e() {
    let t = topo_from_sysfs(M1_SYSFS);
    assert_eq!(t.num_cores(), 8);
    assert_eq!(t.clusters.len(), 2, "{:?}", t.clusters);
    assert_eq!(t.clusters[0].kind, ClusterKind::Performance);
    assert_eq!(t.clusters[1].kind, ClusterKind::Efficiency);
    assert_eq!(t.perf_cores(), vec![0, 1, 2, 3]);
    assert_eq!(t.efficiency_cores(), vec![4, 5, 6, 7]);
}

#[test]
fn placement_fixture_flat_x86_is_one_performance_class() {
    let t = topo_from_sysfs(FLAT_SYSFS);
    assert_eq!(t.num_cores(), 8);
    // No capacity + private L2s: symmetric server. One cluster, all
    // performance — per-core L2 groups must not shatter the class.
    assert_eq!(t.clusters.len(), 1, "{:?}", t.clusters);
    assert_eq!(t.clusters[0].kind, ClusterKind::Performance);
    assert_eq!(t.perf_cores(), (0..8).collect::<Vec<_>>());
    assert!(t.efficiency_cores().is_empty());
}

#[test]
fn placement_fixture_single_core_is_minimal() {
    let t = topo_from_sysfs(SINGLE_SYSFS);
    assert_eq!(t.num_cores(), 1);
    assert_eq!(t.clusters.len(), 1);
    assert_eq!(t.perf_cores(), vec![0]);
}

#[test]
fn placement_fixture_m1_sysctl_parses_perflevels() {
    let (p, e) = CpuTopology::parse_sysctl_snapshot(M1_SYSCTL).expect("fixture parses");
    assert_eq!((p, e), (4, 4));
    let t = CpuTopology::from_perflevels(p, e);
    assert_eq!(t.perf_cores(), vec![0, 1, 2, 3]);
    assert_eq!(t.efficiency_cores(), vec![4, 5, 6, 7]);
}

/// Property: every policy yields a valid, non-empty core set for every
/// worker index across pool sizes 1..32, on every synthetic topology —
/// and each named core actually exists in the topology.
#[test]
fn placement_every_policy_yields_valid_core_sets() {
    let topologies = vec![
        CpuTopology::apple_like(),
        CpuTopology::flat(1),
        CpuTopology::flat(6),
        topo_from_sysfs(M1_SYSFS),
        topo_from_sysfs(FLAT_SYSFS),
        topo_from_sysfs(SINGLE_SYSFS),
    ];
    for topo in &topologies {
        let all: Vec<usize> = topo
            .clusters
            .iter()
            .flat_map(|c| c.cores.iter().copied())
            .collect();
        for policy in PlacementPolicy::all() {
            for workers in 1..32usize {
                for w in 0..workers {
                    let cores = core_set(policy, topo, w, workers);
                    assert!(
                        !cores.is_empty(),
                        "{policy} worker {w}/{workers} on {} got no cores",
                        topo.describe()
                    );
                    for c in &cores {
                        assert!(
                            all.contains(c),
                            "{policy} worker {w}/{workers} names core {c} \
                             outside {}",
                            topo.describe()
                        );
                    }
                }
            }
        }
    }
}

fn model_cfg(threads: usize) -> ModelConfig {
    ModelConfig::from_json(&format!(
        r#"{{"name":"place","dims":[24,48,24],"sparsity":0.3,"seed":17,
            "threads":{threads}}}"#
    ))
    .unwrap()
}

fn planner_with(policy: PlacementPolicy) -> Arc<Planner> {
    let planner = Planner::new().with_topology(CpuTopology::apple_like());
    planner.set_placement(policy);
    Arc::new(planner)
}

/// The tentpole guarantee: batched forwards are bitwise-identical across
/// every placement policy × thread counts 1–4. The synthetic apple-like
/// topology names cores the host may not have, so pins may *fail* —
/// placement is best-effort and identity must hold regardless.
#[test]
fn placement_forward_is_bitwise_identical_across_policies_and_threads() {
    let ms = [1usize, 3, 8];
    let xs: Vec<Matrix> = ms
        .iter()
        .map(|&m| Matrix::random(m, 24, 900 + m as u64))
        .collect();
    // Sequential, unplaced reference.
    let reference: Vec<Matrix> = {
        let mlp = TernaryMlp::planned(&model_cfg(1), &planner_with(PlacementPolicy::None))
            .unwrap();
        xs.iter().map(|x| mlp.forward(x).unwrap()).collect()
    };
    for policy in PlacementPolicy::all() {
        for threads in 1..=4usize {
            let mlp =
                TernaryMlp::planned(&model_cfg(threads), &planner_with(policy)).unwrap();
            for (x, want) in xs.iter().zip(&reference) {
                let got = mlp.forward(x).unwrap();
                assert_eq!(got.rows(), want.rows());
                for i in 0..got.rows() {
                    for j in 0..got.cols() {
                        assert_eq!(
                            got.row(i)[j].to_bits(),
                            want.row(i)[j].to_bits(),
                            "policy {policy}, threads {threads}, M {}, \
                             cell ({i},{j})",
                            x.rows()
                        );
                    }
                }
            }
        }
    }
}

/// Decode half of the identity guarantee: the M=1-pinned decode plan
/// produces bitwise-identical steps under every placement policy ×
/// thread counts 1–4.
#[test]
fn placement_decode_plan_is_bitwise_identical_across_policies() {
    let d = 24usize;
    let x = Matrix::random(2, d, 77);
    let reference: Matrix = {
        let mlp = TernaryMlp::planned(&model_cfg(1), &planner_with(PlacementPolicy::None))
            .unwrap();
        let cache = Arc::clone(mlp.plan_cache().unwrap());
        let plan = cache.decode_plan(2).unwrap();
        let mut y = Matrix::zeros(2, d);
        plan.run(&x, &mut y).unwrap();
        y
    };
    for policy in PlacementPolicy::all() {
        for threads in 1..=4usize {
            let mlp =
                TernaryMlp::planned(&model_cfg(threads), &planner_with(policy)).unwrap();
            let cache = Arc::clone(mlp.plan_cache().unwrap());
            let plan = cache.decode_plan(2).unwrap();
            for step in 0..3 {
                let mut y = Matrix::zeros(2, d);
                plan.run(&x, &mut y).unwrap();
                for j in 0..d {
                    for i in 0..2 {
                        assert_eq!(
                            y.row(i)[j].to_bits(),
                            reference.row(i)[j].to_bits(),
                            "decode policy {policy}, threads {threads}, \
                             step {step}, cell ({i},{j})"
                        );
                    }
                }
            }
        }
    }
}

/// Planner-level wiring: the placement policy set before the lazy pool
/// creation sizes the pool by the perf-core budget and yields per-worker
/// placement rows (outcomes are best-effort — the synthetic topology's
/// cores may not exist on the host — but every row must be present).
#[test]
fn placement_rows_appear_once_the_shared_pool_exists() {
    let planner = planner_with(PlacementPolicy::Compact);
    assert!(planner.pool_placements().is_empty(), "pool is lazy");
    // A threaded plan forces the shared pool into existence.
    let mlp = TernaryMlp::planned(&model_cfg(3), &planner).unwrap();
    let _ = mlp.forward(&Matrix::random(8, 24, 5)).unwrap();
    let rows = planner.pool_placements();
    assert!(!rows.is_empty(), "placed pool reports its workers");
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.worker, i, "rows are sorted by worker index");
        assert!(!row.cores.is_empty(), "compact workers name a core each");
    }
}
