//! Property tests over sparse formats: every format round-trips to the
//! exact dense ternary matrix it was built from, preserves nnz, validates,
//! and reports a positive byte size, across randomized shapes, sparsities
//! and parameters.

use stgemm::formats::*;
use stgemm::ternary::TernaryMatrix;
use stgemm::util::quickcheck::{props, Gen};

fn random_w(g: &mut Gen) -> TernaryMatrix {
    let k = g.usize(1, 200);
    let n = g.usize(1, 64);
    let s = *g.choose(&[0.0f32, 0.0625, 0.125, 0.25, 0.5, 0.9, 1.0]);
    TernaryMatrix::random(k, n, s, g.seed())
}

#[test]
fn prop_tcsc_roundtrip() {
    props("tcsc roundtrip", 60, |g| {
        let w = random_w(g);
        let f = Tcsc::from_ternary(&w);
        f.validate().unwrap();
        assert_eq!(f.to_dense(), w);
        assert_eq!(f.nnz(), w.nnz());
        assert!(f.bytes() > 0);
    });
}

#[test]
fn prop_blocked_roundtrip_any_block_size() {
    props("blocked roundtrip", 60, |g| {
        let w = random_w(g);
        let bs = g.usize(1, w.k().max(1) * 2);
        let f = BlockedTcsc::from_ternary(&w, bs);
        f.validate().unwrap();
        assert_eq!(f.to_dense(), w);
        assert_eq!(f.nnz(), w.nnz());
    });
}

#[test]
fn prop_interleaved_roundtrip_any_group() {
    props("interleaved roundtrip", 60, |g| {
        let w = random_w(g);
        let group = g.usize(1, 8);
        let f = InterleavedTcsc::from_ternary(&w, group);
        f.validate().unwrap();
        assert_eq!(f.to_dense(), w);
        assert_eq!(f.nnz(), w.nnz());
    });
}

#[test]
fn prop_interleaved_blocked_roundtrip() {
    props("interleaved blocked roundtrip", 60, |g| {
        let w = random_w(g);
        let bs = g.usize(1, w.k().max(1) * 2);
        let group = g.usize(1, 4);
        let f = InterleavedBlockedTcsc::from_ternary(&w, bs, group);
        f.validate().unwrap();
        assert_eq!(f.to_dense(), w);
        assert_eq!(f.nnz(), w.nnz());
    });
}

#[test]
fn prop_symmetric_roundtrip_and_invariants() {
    props("symmetric roundtrip", 60, |g| {
        let w = random_w(g);
        let f = SymmetricTcsc::from_ternary(&w);
        f.validate().unwrap();
        assert_eq!(f.to_dense(), w);
        assert_eq!(f.nnz(), w.nnz());
        // Symmetry invariant: each group block is steps·16 long, steps even.
        for gi in 0..f.ngroups() {
            assert_eq!(f.steps_per_group[gi] % 2, 0);
            assert_eq!(
                f.group_indices(gi).len(),
                f.steps_per_group[gi] as usize * 16
            );
        }
    });
}

#[test]
fn prop_compressed_roundtrip() {
    props("compressed roundtrip", 60, |g| {
        let w = random_w(g);
        let f = CompressedTernary::from_ternary(&w);
        f.validate().unwrap();
        assert_eq!(f.to_dense(), w);
        // One byte per 5 rows per column.
        assert_eq!(f.bytes(), w.n() * w.k().div_ceil(5));
    });
}

#[test]
fn prop_inverted_roundtrip() {
    props("inverted roundtrip", 60, |g| {
        let w = random_w(g);
        let f = InvertedIndex::from_ternary(&w);
        f.validate().unwrap();
        assert_eq!(f.to_dense(), w);
        assert_eq!(f.nnz(), w.nnz());
    });
}

#[test]
fn prop_formats_agree_on_nnz() {
    props("cross-format nnz agreement", 40, |g| {
        let w = random_w(g);
        let nnz = w.nnz();
        assert_eq!(Tcsc::from_ternary(&w).nnz(), nnz);
        assert_eq!(BlockedTcsc::from_ternary(&w, 16).nnz(), nnz);
        assert_eq!(InterleavedTcsc::from_ternary(&w, 4).nnz(), nnz);
        assert_eq!(InterleavedBlockedTcsc::from_ternary(&w, 16, 2).nnz(), nnz);
        assert_eq!(SymmetricTcsc::from_ternary(&w).nnz(), nnz);
        assert_eq!(InvertedIndex::from_ternary(&w).nnz(), nnz);
    });
}

#[test]
fn prop_exact_sparsity_generator() {
    props("exact sparsity", 80, |g| {
        let k = g.usize(1, 300);
        let n = g.usize(1, 100);
        let s = g.f32(0.0, 1.0);
        let w = TernaryMatrix::random(k, n, s, g.seed());
        let expect = (s as f64 * (k * n) as f64).round() as usize;
        assert_eq!(w.nnz(), expect);
        // Sign balance within 1.
        let pos = w.entries().iter().filter(|&&v| v == 1).count();
        let neg = w.entries().iter().filter(|&&v| v == -1).count();
        assert!(pos.abs_diff(neg) <= 1);
    });
}
