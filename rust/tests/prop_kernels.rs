//! Property tests over the kernel family: every registry kernel agrees
//! with the f64-accumulated dense oracle on randomized problems, fused
//! PReLU equals unfused, kernels are deterministic, every
//! [`stgemm::kernels::KernelDescriptor`]'s declared capabilities match the
//! prepared kernel's observable runtime behavior, and the outer-product
//! tile family is **bitwise** identical to the sequential scalar baseline
//! across tile edge cases (K not a multiple of the tile, degenerate M,
//! all-zero columns).

use stgemm::kernels::{
    available_ids, available_kernel_ids, dense_oracle, descriptors, kernel_names, prelu_inplace,
    prepare_kernel, KernelFamily, KernelId, KernelParams,
};
use stgemm::formats::{TileGeometry, MAX_PANEL_WIDTH};
use stgemm::perf::{geometry_candidates, BlockingPolicy, CpuCaps};
use stgemm::tensor::Matrix;
use stgemm::ternary::TernaryMatrix;
use stgemm::util::quickcheck::{props, Gen};

struct Case {
    w: TernaryMatrix,
    x: Matrix,
    bias: Vec<f32>,
}

fn random_case(g: &mut Gen) -> Case {
    let m = g.usize(1, 12);
    let k = g.usize(1, 180);
    let n = g.usize(1, 48);
    let s = *g.choose(&[0.0f32, 0.0625, 0.125, 0.25, 0.5, 1.0]);
    let w = TernaryMatrix::random(k, n, s, g.seed());
    let x = Matrix::random(m, k, g.seed());
    let bias = g.f32_vec(n, -1.0, 1.0);
    Case { w, x, bias }
}

#[test]
fn prop_every_kernel_matches_oracle() {
    props("all kernels vs oracle", 30, |g| {
        let c = random_case(g);
        let oracle = dense_oracle(&c.x, &c.w, &c.bias);
        for &name in kernel_names() {
            let kern = prepare_kernel(name, &c.w, KernelParams::default()).unwrap();
            let mut y = Matrix::zeros(c.x.rows(), c.w.n());
            kern.run(&c.x, &c.bias, &mut y);
            assert!(
                y.allclose(&oracle, 2e-3),
                "kernel {name} maxΔ {}",
                y.max_abs_diff(&oracle)
            );
        }
    });
}

#[test]
fn prop_descriptor_capabilities_match_runtime_on_random_shapes() {
    // Satellite: the descriptor table is internally consistent (unique
    // names, derived enumerations match) and every descriptor prepares
    // successfully on random shapes with runtime behavior — fused PReLU,
    // padded-scratch use, interleave-group honoring — exactly as declared.
    props("descriptor capabilities vs runtime", 20, |g| {
        let c = random_case(g);
        let names: Vec<&str> = descriptors().iter().map(|d| d.name).collect();
        assert_eq!(kernel_names(), names.as_slice(), "derived name list");
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len(), "kernel names must be unique");
        let with_prelu = KernelParams {
            prelu_alpha: Some(0.25),
            ..Default::default()
        };
        for d in descriptors() {
            assert_eq!(KernelId::parse(d.name), Some(d.id), "{}", d.name);
            let plain = d.id.prepare(&c.w, KernelParams::default()).unwrap();
            assert_eq!(plain.name(), d.name);
            assert!(!plain.fused_prelu(), "{}: no PReLU requested", d.name);
            assert_eq!(
                plain.uses_padded_scratch(),
                d.uses_padded_scratch,
                "{}: padded-scratch capability",
                d.name
            );
            assert_eq!(
                plain.uses_tile_scratch(),
                d.uses_tile_scratch,
                "{}: tile-scratch capability",
                d.name
            );
            assert_eq!(
                plain.interleave_group(),
                d.default_group,
                "{}: default interleave group",
                d.name
            );
            let fused = d.id.prepare(&c.w, with_prelu).unwrap();
            assert_eq!(
                fused.fused_prelu(),
                d.supports_fused_prelu,
                "{}: fused-PReLU capability",
                d.name
            );
            if d.uses_group {
                let params = KernelParams {
                    group: Some(3),
                    ..Default::default()
                };
                let kern = d.id.prepare(&c.w, params).unwrap();
                assert_eq!(kern.interleave_group(), Some(3), "{}: honors group", d.name);
            }
        }
    });
}

#[test]
fn capability_gated_descriptor_availability_is_consistent() {
    // Selection-time availability derives purely from descriptor
    // `requires` vs a capability set; construction stays host-agnostic
    // (the descriptor prop test prepares every kernel on every host).
    let scalar = available_ids(&CpuCaps::scalar_only());
    let apple = available_ids(&CpuCaps::apple_like());
    for d in descriptors() {
        assert_eq!(
            scalar.contains(&d.id),
            d.requires.is_empty(),
            "{}: scalar-only availability must equal 'no requirements'",
            d.name
        );
        assert!(
            apple.contains(&d.id),
            "{}: apple-like capability set sees the full registry",
            d.name
        );
    }
    // The cached host list agrees with a fresh query, and everything in
    // it is runnable here.
    let host = CpuCaps::host();
    assert_eq!(available_kernel_ids(), available_ids(&host).as_slice());
    for id in available_kernel_ids() {
        assert!(host.satisfies(id.descriptor().requires), "{id}");
    }
}

#[test]
fn prop_outer_family_bitwise_matches_sequential_baseline() {
    // The tile family's contract is stronger than allclose: streams are
    // (k,c)-lexicographic, so each cell accumulates in exactly the
    // baseline's k-ascending pos-then-neg order — outputs must be
    // bit-identical. Shapes stress the tile edges: K not a multiple of
    // the tile width, M in {0, 1, 3, odd}, all-zero columns via s = 0.
    props("outer family bitwise vs base", 30, |g| {
        let m = *g.choose(&[0usize, 1, 3, 5, 7, 8, 11, 16]);
        let k = g.usize(1, 200);
        let n = g.usize(1, 40);
        let s = *g.choose(&[0.0f32, 0.0625, 0.25, 0.5, 1.0]);
        let w = TernaryMatrix::random(k, n, s, g.seed());
        let x = Matrix::random(m, k, g.seed());
        let bias = g.f32_vec(n, -1.0, 1.0);
        let base = KernelId::BaseTcsc
            .prepare(&w, KernelParams::default())
            .unwrap();
        let mut want = Matrix::zeros(m, n);
        base.run(&x, &bias, &mut want);
        let outer: Vec<_> = descriptors()
            .iter()
            .filter(|d| d.family == KernelFamily::OuterProduct)
            .collect();
        assert_eq!(outer.len(), 2, "scalar emulation + SIMD tile variants");
        for d in outer {
            let kern = d.id.prepare(&w, KernelParams::default()).unwrap();
            let mut y = Matrix::zeros(m, n);
            kern.run(&x, &bias, &mut y);
            assert_eq!(y, want, "{} must be bitwise-identical to the baseline", d.name);
        }
    });
}

#[test]
fn prop_tile_geometry_bitwise_matches_baseline_at_blocking_edges() {
    // The geometry axis is layout, never arithmetic: at ANY panel width ×
    // K-block — including pathological ones the policy would never pick —
    // the tile kernels must stay bitwise-identical to the sequential
    // baseline. Edges stressed: K % block ≠ 0 (blocks of 1/3/7), block ≥ K
    // (one short slice), 8-wide panels over N not a multiple of 8 (ragged
    // last panel), degenerate M.
    props("tile geometry bitwise vs base", 25, |g| {
        let m = *g.choose(&[0usize, 1, 3, 8, 13]);
        let k = g.usize(1, 160);
        let n = *g.choose(&[1usize, 3, 7, 8, 9, 15, 31, 40]);
        let s = *g.choose(&[0.0f32, 0.0625, 0.25, 0.5, 1.0]);
        let w = TernaryMatrix::random(k, n, s, g.seed());
        let x = Matrix::random(m, k, g.seed());
        let bias = g.f32_vec(n, -1.0, 1.0);
        let base = KernelId::BaseTcsc
            .prepare(&w, KernelParams::default())
            .unwrap();
        let mut want = Matrix::zeros(m, n);
        base.run(&x, &bias, &mut want);
        for width in [stgemm::formats::OUTER_TILE, MAX_PANEL_WIDTH] {
            for kb in [0usize, 1, 3, 7, k, k + 5] {
                let geom = TileGeometry::new(width, kb);
                let params = KernelParams {
                    geometry: Some(geom),
                    ..Default::default()
                };
                for id in [KernelId::OuterProductTile, KernelId::OuterProductTileSimd] {
                    let kern = id.prepare(&w, params).unwrap();
                    let mut y = Matrix::zeros(m, n);
                    kern.run(&x, &bias, &mut y);
                    assert_eq!(
                        y, want,
                        "{id} at geometry {geom} must be bitwise-identical to the baseline"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_blocking_policy_is_sane_at_synthetic_cache_extremes() {
    // Satellite: the cache→geometry derivation holds its invariants for
    // ANY synthetic capability snapshot, from absent probes through
    // absurd cache sizes — never an invalid geometry, never an unclamped
    // block, and the documented paper fallbacks exactly when unprobeable.
    use stgemm::perf::blocking::{
        MAX_SCALAR_BLOCK, MAX_TILE_K_BLOCK, MIN_SCALAR_BLOCK, MIN_TILE_K_BLOCK,
        WIDE_PANEL_L1D_BYTES,
    };
    props("blocking policy vs synthetic caps", 40, |g| {
        let mut caps = CpuCaps::scalar_only();
        caps.l1d_bytes = match g.usize(0, 4) {
            0 => None,
            _ => Some(g.usize(1, 1 << 30)),
        };
        caps.l2_bytes = match g.usize(0, 4) {
            0 => None,
            _ => Some(g.usize(1, 1 << 33)),
        };
        let policy = BlockingPolicy::for_caps(&caps);
        policy.geometry.validate().unwrap();
        match caps.l1d_bytes {
            None => {
                // Unprobeable host ⇒ exactly the pre-policy behaviour.
                assert_eq!(policy.scalar_block, stgemm::PAPER_BLOCK_SIZE);
                assert_eq!(policy.geometry, TileGeometry::DEFAULT);
            }
            Some(l1d) => {
                assert!(
                    (MIN_SCALAR_BLOCK..=MAX_SCALAR_BLOCK).contains(&policy.scalar_block),
                    "scalar block {} unclamped for l1d {l1d}",
                    policy.scalar_block
                );
                assert!(policy.scalar_block.is_power_of_two());
                assert!(
                    (MIN_TILE_K_BLOCK..=MAX_TILE_K_BLOCK).contains(&policy.geometry.k_block),
                    "tile K-block {} unclamped for l1d {l1d}",
                    policy.geometry.k_block
                );
                assert!(policy.geometry.k_block.is_power_of_two());
                assert_eq!(
                    policy.geometry.panel_width == MAX_PANEL_WIDTH,
                    l1d >= WIDE_PANEL_L1D_BYTES,
                    "wide panels iff L1d ≥ threshold (l1d {l1d})"
                );
            }
        }
        // The race/sweep candidate grid: default-first, small, deduped,
        // every candidate buildable.
        let grid = geometry_candidates(&caps);
        assert!(!grid.is_empty() && grid.len() <= 4);
        assert_eq!(grid[0], TileGeometry::DEFAULT, "default geometry leads");
        for (i, cand) in grid.iter().enumerate() {
            cand.validate().unwrap();
            assert!(!grid[..i].contains(cand), "duplicate candidate {cand}");
        }
        // Derivation is pure: same snapshot, same policy.
        assert_eq!(policy, BlockingPolicy::for_caps(&caps));
    });
}

#[test]
fn prop_fused_prelu_equals_unfused() {
    props("fused prelu equivalence", 30, |g| {
        let c = random_case(g);
        let alpha = g.f32(0.0, 1.0);
        let mut oracle = dense_oracle(&c.x, &c.w, &c.bias);
        prelu_inplace(&mut oracle, alpha);
        let params = KernelParams {
            prelu_alpha: Some(alpha),
            ..Default::default()
        };
        for name in ["simd_vertical", "simd_horizontal", "simd_blocked_interleaved"] {
            let kern = prepare_kernel(name, &c.w, params).unwrap();
            let mut y = Matrix::zeros(c.x.rows(), c.w.n());
            kern.run(&c.x, &c.bias, &mut y);
            assert!(
                y.allclose(&oracle, 2e-3),
                "kernel {name} maxΔ {}",
                y.max_abs_diff(&oracle)
            );
        }
    });
}

#[test]
fn prop_kernels_deterministic() {
    props("kernel determinism", 20, |g| {
        let c = random_case(g);
        let name = *g.choose(kernel_names());
        let kern = prepare_kernel(name, &c.w, KernelParams::default()).unwrap();
        let mut y1 = Matrix::zeros(c.x.rows(), c.w.n());
        let mut y2 = Matrix::zeros(c.x.rows(), c.w.n());
        kern.run(&c.x, &c.bias, &mut y1);
        kern.run(&c.x, &c.bias, &mut y2);
        assert_eq!(y1, y2, "kernel {name} must be bit-deterministic");
    });
}

#[test]
fn prop_block_size_invariance() {
    // The blocked kernels must give identical math for ANY block size.
    props("block size invariance", 25, |g| {
        let c = random_case(g);
        let oracle = dense_oracle(&c.x, &c.w, &c.bias);
        for bs in [1, 3, 16, 4096] {
            let params = KernelParams {
                block_size: bs,
                ..Default::default()
            };
            for name in ["unrolled_blocked_tcsc_k4_m4", "interleaved_blocked_tcsc"] {
                let kern = prepare_kernel(name, &c.w, params).unwrap();
                let mut y = Matrix::zeros(c.x.rows(), c.w.n());
                kern.run(&c.x, &c.bias, &mut y);
                assert!(y.allclose(&oracle, 2e-3), "{name} bs={bs}");
            }
        }
    });
}

#[test]
fn prop_quantizer_roundtrip_signs() {
    use stgemm::ternary::quantize_absmean;
    props("quantizer sign preservation", 40, |g| {
        let rows = g.usize(1, 32);
        let cols = g.usize(1, 32);
        let w = Matrix::random(rows, cols, g.seed());
        let q = quantize_absmean(&w);
        assert!(q.scale > 0.0);
        for i in 0..rows {
            for j in 0..cols {
                let t = q.weights.get(i, j);
                // A quantized nonzero never flips sign.
                if t != 0 {
                    assert_eq!((t as f32).signum(), w[(i, j)].signum());
                }
            }
        }
    });
}

#[test]
fn prop_flops_model_matches_exact_nnz() {
    use stgemm::perf::flops::CostModel;
    props("cost model exactness", 40, |g| {
        let m = g.usize(1, 16);
        let k = g.usize(1, 128);
        let n = g.usize(1, 64);
        let s = *g.choose(&[0.0625f32, 0.125, 0.25, 0.5]);
        let w = TernaryMatrix::random(k, n, s, g.seed());
        let model = CostModel::new(m, k, n, s);
        // Exact generator: nnz = round(s·K·N), so the nominal model can
        // differ by at most the 0.5-nnz rounding, i.e. m/2 flops.
        let diff = (model.flops() - model.flops_exact(w.nnz())).abs();
        assert!(diff <= m as f64 * 0.5 + 1e-9, "diff {diff} > m/2");
    });
}
