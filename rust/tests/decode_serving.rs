//! PR 9 property tests: the decode-serving subsystem's standing
//! invariants.
//!
//! 1. **Bitwise identity** — a continuously-batched decode step is
//!    bitwise-identical (`f32::to_bits`) to running each session's step
//!    as an independent M=1 forward, across session counts, join/leave
//!    churn, and thread counts 1–4. This holds by construction (the
//!    decode plan pins every layer's kernel to its M=1 choice, and each
//!    output row of a row-partitioned GEMM depends only on its own input
//!    row); these tests are the regression net around that construction.
//! 2. **Zero steady-state allocation** — once the first wave of sessions
//!    has populated the decode arena, further session churn leases only
//!    returned buffer pairs ([`stgemm::plan::ArenaStats`] is the
//!    witness).
//! 3. **Serving-path teardown** — a client that hangs up mid-stream has
//!    its session retired by the scheduler, observed end-to-end through
//!    the HTTP server.

use std::sync::Arc;
use std::time::Duration;

use stgemm::coordinator::server::{http_request_stream, Server, ServerConfig};
use stgemm::coordinator::{
    DecodeConfig, DecodeScheduler, DecodeStream, LoadOptions, Metrics, ModelRegistry,
    Router,
};
use stgemm::model::{ModelConfig, TernaryMlp};
use stgemm::plan::{PlanCache, Planner};
use stgemm::tensor::Matrix;

const D: usize = 24;

/// A square two-layer model (the decode feedback loop needs
/// `d_in == d_out`) with the cache's thread ceiling set to `threads`.
fn cache_for(threads: usize) -> Arc<PlanCache> {
    let cfg = ModelConfig::from_json(&format!(
        r#"{{"name":"dec","dims":[{D},48,{D}],"sparsity":0.3,"seed":11,
            "threads":{threads}}}"#
    ))
    .unwrap();
    let mlp = TernaryMlp::planned(&cfg, &Arc::new(Planner::new())).unwrap();
    Arc::clone(mlp.plan_cache().expect("config-built model has a cache"))
}

fn scheduler(threads: usize, max_sessions: usize) -> Arc<DecodeScheduler> {
    Arc::new(
        DecodeScheduler::new(
            "dec",
            &cache_for(threads),
            Arc::new(Metrics::new()),
            DecodeConfig {
                max_sessions,
                default_max_tokens: 4,
                ..DecodeConfig::default()
            },
        )
        .unwrap(),
    )
}

fn prompt(seed: u64) -> Vec<f32> {
    Matrix::random(1, D, seed).row(0).to_vec()
}

/// Drain a stream's buffered tokens (the schedulers here are stepped
/// manually, so everything a session will ever emit is already in its
/// channel once the step loop runs dry).
fn tokens_of(stream: &DecodeStream) -> Vec<u32> {
    let mut out = Vec::new();
    while let Some(ev) = stream.next() {
        assert_eq!(ev.index, out.len(), "token indices are dense");
        out.push(ev.token);
    }
    out
}

#[test]
fn decode_batched_step_is_bitwise_identical_to_independent_forwards() {
    for &threads in &[1usize, 2, 4] {
        let cache = cache_for(threads);
        let plan_1 = cache.decode_plan(1).unwrap();
        for &m in &[1usize, 2, 3, 5] {
            let plan_n = cache.decode_plan(m).unwrap();
            // M state rows, iterated through 4 feedback steps.
            let mut batched = Matrix::zeros(m, D);
            let mut solo: Vec<Vec<f32>> = (0..m)
                .map(|i| prompt(300 + (m * 10 + i) as u64))
                .collect();
            for (i, row) in solo.iter().enumerate() {
                batched.row_mut(i).copy_from_slice(row);
            }
            for step in 0..4 {
                let mut y = Matrix::zeros(m, D);
                plan_n.run(&batched, &mut y).unwrap();
                for i in 0..m {
                    // The same row as an independent forward — once
                    // through the batch plan at M=1, once through the
                    // dedicated M=1 plan.
                    let mut via_n = vec![0f32; D];
                    Matrix::with_view(&solo[i], 1, D, |x| {
                        Matrix::with_view_mut(&mut via_n, 1, D, |y1| {
                            plan_n.run(x, y1).map(|_| ())
                        })
                    })
                    .unwrap();
                    let mut via_1 = vec![0f32; D];
                    Matrix::with_view(&solo[i], 1, D, |x| {
                        Matrix::with_view_mut(&mut via_1, 1, D, |y1| {
                            plan_1.run(x, y1).map(|_| ())
                        })
                    })
                    .unwrap();
                    for j in 0..D {
                        let b = y.row(i)[j].to_bits();
                        assert_eq!(
                            b,
                            via_n[j].to_bits(),
                            "batched row {i} ≠ its M=1 forward through the \
                             same plan (threads {threads}, m {m}, step {step}, col {j})"
                        );
                        assert_eq!(
                            b,
                            via_1[j].to_bits(),
                            "batched row {i} ≠ the dedicated M=1 plan \
                             (threads {threads}, m {m}, step {step}, col {j})"
                        );
                    }
                    solo[i] = via_n;
                }
                // Feed the batch output back as the next step's input.
                for i in 0..m {
                    batched.row_mut(i).copy_from_slice(y.row(i));
                }
            }
        }
    }
}

#[test]
fn decode_token_streams_are_identical_across_batching_churn_and_threads() {
    // (prompt seed, token budget) per session; budgets differ so sessions
    // leave the batch at different steps.
    let specs: [(u64, usize); 5] = [(21, 4), (22, 6), (23, 3), (24, 5), (25, 2)];
    let prompts: Vec<Vec<f32>> = specs.iter().map(|(s, _)| prompt(*s)).collect();

    // Reference: every session decoded alone, single-threaded, on a
    // capacity-1 scheduler (the tuned M=1 GEMV path).
    let reference: Vec<Vec<u32>> = specs
        .iter()
        .enumerate()
        .map(|(i, (_, budget))| {
            let sched = scheduler(1, 1);
            let stream = sched.begin(&prompts[i], Some(*budget)).unwrap();
            while sched.step().unwrap() > 0 {}
            tokens_of(&stream)
        })
        .collect();
    for (i, toks) in reference.iter().enumerate() {
        assert_eq!(toks.len(), specs[i].1, "reference session {i} ran its budget");
    }

    for &threads in &[1usize, 2, 3, 4] {
        let sched = scheduler(threads, 5);
        // Join/leave churn: three sessions up front, one batched step,
        // two more join mid-decode, one leaves (client disconnect), then
        // the scheduler runs dry.
        let mut streams: Vec<Option<DecodeStream>> = (0..3)
            .map(|i| Some(sched.begin(&prompts[i], Some(specs[i].1)).unwrap()))
            .collect();
        sched.step().unwrap();
        for i in 3..5 {
            streams.push(Some(sched.begin(&prompts[i], Some(specs[i].1)).unwrap()));
        }
        sched.step().unwrap();
        drop(streams[1].take()); // leave: canceled before the next step
        while sched.step().unwrap() > 0 {}
        for (i, slot) in streams.iter().enumerate() {
            let Some(stream) = slot else { continue };
            assert_eq!(
                tokens_of(stream),
                reference[i],
                "session {i} diverged under churn at {threads} thread(s)"
            );
        }
    }
}

#[test]
fn decode_steady_state_allocates_nothing() {
    let sched = scheduler(2, 4);
    let run_wave = |wave: u64| {
        let streams: Vec<DecodeStream> = (0..4u64)
            .map(|i| sched.begin(&prompt(40 + 10 * wave + i), Some(3)).unwrap())
            .collect();
        while sched.step().unwrap() > 0 {}
        for s in &streams {
            assert_eq!(tokens_of(s).len(), 3);
        }
    };
    run_wave(0);
    let after_first = sched.arena_stats().allocations;
    assert!(after_first > 0, "the first wave populates the arena");
    for wave in 1..4 {
        run_wave(wave);
    }
    let stats = sched.arena_stats();
    assert_eq!(
        stats.allocations, after_first,
        "session churn after the first wave must lease only returned pairs"
    );
    assert!(stats.reuses > 0, "later waves reuse the wave-1 pairs");
}

#[test]
fn decode_http_disconnect_retires_the_session() {
    let registry = Arc::new(ModelRegistry::with_thread_budget(
        Arc::new(Planner::new()),
        4,
    ));
    let cfg = ModelConfig::from_json(&format!(
        r#"{{"name":"sq","dims":[{D},48,{D}],"sparsity":0.3,"seed":11}}"#
    ))
    .unwrap();
    registry.load(&cfg, LoadOptions::default()).unwrap();
    let router = Arc::new(Router::with_registry(Arc::clone(&registry)));
    let server = Server::start(Arc::clone(&router), ServerConfig::default()).unwrap();

    // A stream with an enormous budget, abandoned after three chunks.
    let body = format!(
        r#"{{"model":"sq","prompt":[{}],"max_tokens":1000000}}"#,
        prompt(9)
            .iter()
            .map(|v| format!("{v:.6}"))
            .collect::<Vec<_>>()
            .join(",")
    );
    let mut seen = 0usize;
    let (status, _) = http_request_stream(
        &server.local_addr,
        "POST",
        "/generate",
        &body,
        Duration::from_secs(10),
        |_| {
            seen += 1;
            seen < 3 // hang up after the third token
        },
    )
    .unwrap();
    assert_eq!(status, 200);
    assert_eq!(seen, 3);

    // The server notices the dead socket on a chunk write and drops the
    // stream; the scheduler retires the session before its next step.
    let sched = registry
        .get("sq")
        .unwrap()
        .decode_scheduler_if_started()
        .expect("the /generate call started the scheduler");
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while sched.active_sessions() > 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "disconnected client's session was never retired"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    registry.shutdown();
}
