//! Paper Figures 2–4: unroll-factor grid-search heatmaps.
//! `STGEMM_BENCH_SCALE=full cargo bench --bench fig2_unroll_grid` for the
//! paper shapes (s=25%, M=32, N=1024, K up to 16384).

use stgemm::bench::figures::fig2_unroll_grid;
use stgemm::bench::harness::BenchScale;
use stgemm::bench::report::write_csv;

fn main() {
    let scale = BenchScale::from_env();
    for (i, table) in fig2_unroll_grid(scale).into_iter().enumerate() {
        println!("{}", table.render());
        if let Ok(p) = write_csv(&table, &format!("fig2_grid_{i}.csv")) {
            println!("  [csv] {}\n", p.display());
        }
    }
}
