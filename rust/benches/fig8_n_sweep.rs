//! Paper Fig 8: N-invariance — performance constant across N at fixed
//! K=8192, M=8 (the property that makes dynamic batching free).

use stgemm::bench::figures::fig8_n_sweep;
use stgemm::bench::harness::BenchScale;
use stgemm::bench::report::write_csv;

fn main() {
    let table = fig8_n_sweep(BenchScale::from_env());
    println!("{}", table.render());
    if let Ok(p) = write_csv(&table, "fig8_n_sweep.csv") {
        println!("  [csv] {}", p.display());
    }
}
