//! Paper Fig 11: vectorized kernels (vertical, horizontal, vectorized
//! best-scalar) vs base and best scalar, PReLU fused, s=25%, M=N=1024.

use stgemm::bench::figures::fig11_simd;
use stgemm::bench::harness::BenchScale;
use stgemm::bench::report::write_csv;

fn main() {
    let table = fig11_simd(BenchScale::from_env());
    println!("{}", table.render());
    if let Ok(p) = write_csv(&table, "fig11_simd.csv") {
        println!("  [csv] {}", p.display());
    }
}
