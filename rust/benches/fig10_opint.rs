//! Paper Fig 10: operational-intensity heatmap (analytic, same traffic
//! estimate as the paper: exact format size + X + Y + b).

use stgemm::bench::figures::fig10_opint;
use stgemm::bench::report::write_csv;

fn main() {
    let table = fig10_opint();
    println!("{}", table.render());
    if let Ok(p) = write_csv(&table, "fig10_opint.csv") {
        println!("  [csv] {}", p.display());
    }
}
