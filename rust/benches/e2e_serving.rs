//! E11: end-to-end serving benchmark — the coordinator serving the ternary
//! FFN under concurrent load, native backend vs (when artifacts exist) the
//! PJRT/XLA backend, reporting throughput, latency percentiles and batcher
//! effectiveness.

use std::sync::Arc;
use std::time::Duration;

use stgemm::bench::harness::BenchScale;
use stgemm::bench::report::{write_csv, Table};
use stgemm::coordinator::{Backend, BatchPolicy, Engine, LoadGenerator, Router};
use stgemm::model::{ModelConfig, TernaryLinear, TernaryMlp};
use stgemm::plan::{PlanHints, Planner};
use stgemm::runtime::{Manifest, XlaExecutor};

fn bench_backend(name: &str, engine: Engine, clients: usize, reqs: usize) -> Vec<String> {
    let d_in = engine.d_in();
    let mut router = Router::new();
    router.register(
        engine,
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
        },
    );
    let router = Arc::new(router);
    let gen = LoadGenerator {
        clients,
        requests_per_client: reqs,
        d_in,
        model: name.to_string(),
        seed: 7,
    };
    let report = gen.run_inprocess(&router);
    vec![
        name.to_string(),
        format!("{}", report.total_requests),
        format!("{:.0}", report.throughput_rps),
        format!("{}", report.latency_us_p50),
        format!("{}", report.latency_us_p95),
        format!("{}", report.latency_us_p99),
        format!("{:.2}", report.mean_batch_size),
        format!("{}", report.errors),
    ]
}

fn main() {
    let scale = BenchScale::from_env();
    let (clients, reqs) = match scale {
        BenchScale::Full => (16, 200),
        BenchScale::Ci => (4, 25),
    };
    let mut table = Table::new(
        format!("E2E serving: ternary FFN 256→1024→256, {clients} clients × {reqs} reqs"),
        &[
            "backend",
            "requests",
            "req/s",
            "p50 µs",
            "p95 µs",
            "p99 µs",
            "mean batch",
            "errors",
        ],
    );

    // Native backend on the synthetic config, through the serving path
    // proper: planner-selected kernels, M-bucketed plan cache.
    let cfg = ModelConfig::from_json(
        r#"{"name":"native","dims":[256,1024,256],"sparsity":0.25,"seed":4321}"#,
    )
    .unwrap();
    let engine = Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
    table.row(bench_backend("native", engine, clients, reqs));

    // Also native with the baseline kernel — the explicit-override escape
    // hatch (config `kernel` key), kept to show what the paper's
    // optimizations buy at the serving level.
    let cfg_base = ModelConfig::from_json(
        r#"{"name":"native_base","dims":[256,1024,256],"sparsity":0.25,"seed":4321,
            "kernel":"base_tcsc"}"#,
    )
    .unwrap();
    let engine = Engine::from_config(&cfg_base, &Arc::new(Planner::new())).unwrap();
    table.row(bench_backend("native_base", engine, clients, reqs));

    // XLA backend from the real artifact (identical weights via manifest).
    match Manifest::load("artifacts") {
        Ok(manifest) if !manifest.variants_of("ffn_e2e").is_empty() => {
            let planner = Planner::new();
            let hints = PlanHints {
                expected_batch: 8,
                ..Default::default()
            };
            let v0 = manifest.variants_of("ffn_e2e")[0];
            let mut layers = Vec::new();
            for (i, l) in v0.layers.iter().enumerate() {
                let w = v0.load_weights(&manifest.dir, i).expect("weights");
                let b = v0.load_bias(&manifest.dir, i).expect("bias");
                layers.push(
                    TernaryLinear::planned(&planner, &w, b, 1.0, l.prelu_alpha, &hints)
                        .unwrap(),
                );
            }
            let mlp = TernaryMlp::from_layers("xla".into(), layers).unwrap();
            let xla = XlaExecutor::spawn(&manifest, "ffn_e2e").expect("xla");
            let engine = Engine::new("xla", mlp)
                .with_xla(xla)
                .with_backend(Backend::Xla);
            table.row(bench_backend("xla", engine, clients, reqs));
        }
        _ => eprintln!("[e2e] artifacts not found — skipping XLA backend row"),
    }

    println!("{}", table.render());
    if let Ok(p) = write_csv(&table, "e2e_serving.csv") {
        println!("  [csv] {}", p.display());
    }
}
