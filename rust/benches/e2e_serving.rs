//! E11: end-to-end serving benchmark — the coordinator serving the ternary
//! FFN under concurrent load, native backend vs (when artifacts exist) the
//! PJRT/XLA backend, reporting throughput, latency percentiles and batcher
//! effectiveness.
//!
//! PR 5 additions: the native model is served twice — wavefront-pipelined
//! (default) and with the per-layer barrier path (`--no-pipeline`) — and a
//! direct scheduler comparison runs the *same* compiled layer stack in
//! [`PipelineMode::Barrier`] vs [`PipelineMode::Wavefront`], recording
//! per-layer barrier stall time. Everything lands in `e2e_serving.json` so
//! the pipelining win is tracked across PRs.
//!
//! PR 6 additions: a per-kernel-family GFLOP/s section — one representative
//! per [`KernelFamily`], chosen purely through the descriptor capability
//! query (the host's [`CpuCaps`] filter, no kernel-name literals) — plus
//! the serving p50/p99 rows.
//!
//! PR 7 additions: a per-geometry GFLOP/s section — every host-runnable
//! kernel that declares the blocking-geometry axis, measured at each
//! cache-derived panel-width × K-block candidate from
//! [`geometry_candidates`] — so the blocking win (or its absence on this
//! host) is tracked across PRs. Everything lands in `BENCH_pr7.json` at
//! the repo root.
//!
//! PR 8 additions: a two-model skewed-load fleet scenario — a hot model
//! with a small admission queue budget hammered by many clients next to a
//! lightly-loaded cold model, both sharing one registry (one planner, one
//! thread budget, the demand balancer re-splitting it) — reporting
//! per-model throughput, p50/p99 and the hot model's admission-rejection
//! rate into `BENCH_pr8.json` at the repo root.
//!
//! PR 9 additions: decode-serving scenarios — a single autoregressive
//! session on the tuned M=1 GEMV path and concurrent bursty sessions
//! continuously batched into shared steps — reporting tokens/sec,
//! inter-token p50/p99, mean step occupancy and the decode arena's
//! steady-state allocation counters into `BENCH_pr9.json` at the repo
//! root.
//!
//! PR 10 additions: pinned vs unpinned serving — the same wavefront
//! forward and decode workloads run with the shared pool placed on
//! performance cores and with placement off (`--no-pin`) — reporting
//! GFLOP/s, tokens/sec, per-layer stall and how many workers the OS
//! actually pinned, into `BENCH_pr10.json` at the repo root.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use stgemm::bench::harness::{measure_kernel, BenchScale};
use stgemm::bench::report::{write_csv, Table};
use stgemm::coordinator::{
    Backend, BatchPolicy, DecodeConfig, DecodeLoadGen, DecodeScheduler, Engine,
    LoadGenerator, LoadOptions, Metrics, ModelRegistry, Router,
};
use stgemm::kernels::{descriptors, KernelDescriptor, KernelFamily, KernelParams};
use stgemm::model::{ModelConfig, TernaryLinear, TernaryMlp};
use stgemm::perf::{cost_flops, geometry_candidates, CpuCaps, CpuTopology};
use stgemm::plan::{PipelineMode, PipelineStats, PlanHints, Planner};
use stgemm::runtime::{Manifest, XlaExecutor};
use stgemm::tensor::Matrix;
use stgemm::util::json::Json;
use stgemm::util::PlacementPolicy;

struct ServingRow {
    backend: String,
    requests: usize,
    rps: f64,
    p50_us: u64,
    p95_us: u64,
    p99_us: u64,
    mean_batch: f64,
    errors: usize,
}

impl ServingRow {
    fn table_row(&self) -> Vec<String> {
        vec![
            self.backend.clone(),
            format!("{}", self.requests),
            format!("{:.0}", self.rps),
            format!("{}", self.p50_us),
            format!("{}", self.p95_us),
            format!("{}", self.p99_us),
            format!("{:.2}", self.mean_batch),
            format!("{}", self.errors),
        ]
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("backend", Json::str(self.backend.clone())),
            ("requests", Json::num(self.requests as f64)),
            ("rps", Json::num(self.rps)),
            ("p50_us", Json::num(self.p50_us as f64)),
            ("p95_us", Json::num(self.p95_us as f64)),
            ("p99_us", Json::num(self.p99_us as f64)),
            ("mean_batch", Json::num(self.mean_batch)),
            ("errors", Json::num(self.errors as f64)),
        ])
    }
}

fn bench_backend(name: &str, engine: Engine, clients: usize, reqs: usize) -> ServingRow {
    let d_in = engine.d_in();
    let mut router = Router::new();
    router.register(
        engine,
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
        },
    );
    let router = Arc::new(router);
    let gen = LoadGenerator {
        clients,
        requests_per_client: reqs,
        d_in,
        model: name.to_string(),
        seed: 7,
        request_timeout: Duration::from_secs(30),
    };
    let report = gen.run_inprocess(&router);
    ServingRow {
        backend: name.to_string(),
        requests: report.total_requests,
        rps: report.throughput_rps,
        p50_us: report.latency_us_p50,
        p95_us: report.latency_us_p95,
        p99_us: report.latency_us_p99,
        mean_batch: report.mean_batch_size,
        errors: report.errors,
    }
}

/// Aggregate of repeated [`PipelineStats`] for one schedule mode.
#[derive(Default)]
struct ModeAggregate {
    wall_us: u64,
    stall_us: u64,
    max_depth: usize,
    per_layer_stall_us: Vec<u64>,
}

impl ModeAggregate {
    fn absorb(&mut self, stats: &PipelineStats) {
        self.wall_us += stats.wall_us;
        self.stall_us += stats.stall_us;
        self.max_depth = self.max_depth.max(stats.max_depth);
        if self.per_layer_stall_us.len() < stats.per_layer_stall_us.len() {
            self.per_layer_stall_us
                .resize(stats.per_layer_stall_us.len(), 0);
        }
        for (total, s) in self
            .per_layer_stall_us
            .iter_mut()
            .zip(&stats.per_layer_stall_us)
        {
            *total += s;
        }
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("wall_us_total", Json::num(self.wall_us as f64)),
            ("stall_us_total", Json::num(self.stall_us as f64)),
            ("max_depth", Json::num(self.max_depth as f64)),
            (
                "per_layer_stall_us",
                Json::arr(self.per_layer_stall_us.iter().map(|&s| Json::num(s as f64))),
            ),
        ])
    }
}

/// Barrier vs wavefront through the *same* compiled layer stack: the only
/// variable is the dependency graph, so the stall delta is the scheduling
/// win itself (and the per-layer barrier stall is the join tail the
/// wavefront removes).
fn barrier_vs_wavefront(reps: usize) -> Json {
    let (m, threads) = (64usize, 4usize);
    let cfg = ModelConfig::from_json(&format!(
        r#"{{"name":"stall","dims":[256,1024,512,256],"sparsity":0.25,"seed":99,
            "prelu_alpha":0.25,"threads":{threads}}}"#
    ))
    .unwrap();
    let mlp = TernaryMlp::planned(&cfg, &Arc::new(Planner::new())).unwrap();
    let cache = mlp.plan_cache().expect("config-built model");
    let x = Matrix::random(m, 256, 5);
    let mut y = Matrix::zeros(m, 256);
    let mut aggregates = Vec::new();
    for mode in [PipelineMode::Barrier, PipelineMode::Wavefront] {
        let plan = cache.compile_pipeline(m, mode).expect("compile");
        let mut agg = ModeAggregate::default();
        // One warmup run fills scratch/arena outside the measurement.
        plan.run(&x, &mut y).expect("pipeline run");
        for _ in 0..reps {
            agg.absorb(&plan.run(&x, &mut y).expect("pipeline run"));
        }
        aggregates.push(agg);
    }
    let (barrier, wavefront) = (&aggregates[0], &aggregates[1]);
    let speedup = if wavefront.wall_us > 0 {
        barrier.wall_us as f64 / wavefront.wall_us as f64
    } else {
        1.0
    };
    println!(
        "[e2e] barrier vs wavefront (M={m}, {} layers, {threads} threads, {reps} reps): \
         wall {} µs → {} µs ({speedup:.2}x), stall {} µs → {} µs, \
         per-layer barrier stall {:?} µs",
        cfg.dims.len() - 1,
        barrier.wall_us,
        wavefront.wall_us,
        barrier.stall_us,
        wavefront.stall_us,
        barrier.per_layer_stall_us,
    );
    Json::obj(vec![
        ("m", Json::num(m as f64)),
        ("layers", Json::num((cfg.dims.len() - 1) as f64)),
        ("threads", Json::num(threads as f64)),
        ("reps", Json::num(reps as f64)),
        ("barrier", barrier.json()),
        ("wavefront", wavefront.json()),
        ("wavefront_speedup", Json::num(speedup)),
    ])
}

/// One representative kernel per [`KernelFamily`], measured on the serving
/// FFN's hot shape. Representatives come from a pure capability query: the
/// host-available descriptors of each family, preferring a SIMD member
/// (the family at its best on this machine) — no kernel-name literals, so
/// new families land here automatically and a capability-gated kernel is
/// never measured on a host that cannot run it.
fn family_gflops(scale: BenchScale) -> Json {
    let caps = CpuCaps::host();
    let timer = scale.timer();
    let (m, k, n, s) = (64usize, 1024usize, 256usize, 0.25f32);
    let mut families: Vec<KernelFamily> = Vec::new();
    for d in descriptors() {
        if !families.contains(&d.family) {
            families.push(d.family);
        }
    }
    let mut rows = Vec::new();
    for family in families {
        let avail: Vec<&KernelDescriptor> = descriptors()
            .iter()
            .filter(|d| d.family == family && caps.satisfies(d.requires))
            .collect();
        let rep = match avail.iter().find(|d| d.simd).or_else(|| avail.first()) {
            Some(rep) => *rep,
            None => {
                println!("[e2e] family {family:?}: no kernel runnable on this host — skipped");
                continue;
            }
        };
        let meas = measure_kernel(rep.name, m, k, n, s, 42, KernelParams::default(), &timer);
        println!(
            "[e2e] family {family:?}: {} at {:.2} GFLOP/s ({:.3} flops/cycle, M={m} K={k} N={n} s={s})",
            rep.name,
            meas.gflops(),
            meas.flops_per_cycle(),
        );
        rows.push(Json::obj(vec![
            ("family", Json::str(format!("{family:?}"))),
            ("kernel", Json::str(rep.name.to_string())),
            ("m", Json::num(m as f64)),
            ("k", Json::num(k as f64)),
            ("n", Json::num(n as f64)),
            ("sparsity", Json::num(s as f64)),
            ("gflops", Json::num(meas.gflops())),
            ("flops_per_cycle", Json::num(meas.flops_per_cycle())),
        ]));
    }
    Json::arr(rows)
}

/// Per-geometry GFLOP/s for the blocking-geometry axis: every host-runnable
/// kernel whose descriptor declares the axis, measured at each cache-derived
/// panel-width × K-block candidate. Candidates come from the same
/// [`geometry_candidates`] query the planner, plan-cache race and sweep
/// consult (the default geometry is always first), so no geometry spelling
/// is hardcoded here and a host with different caches measures a different —
/// but equally valid — candidate set. The K is deliberately deep (the
/// paper's 4096) so K-blocking has a cache footprint to act on.
fn geometry_gflops(scale: BenchScale) -> Json {
    let caps = CpuCaps::host();
    let timer = scale.timer();
    let (m, k, n, s) = (64usize, 4096usize, 256usize, 0.25f32);
    let candidates = geometry_candidates(&caps);
    let mut rows = Vec::new();
    for d in descriptors() {
        if !d.geometry || !caps.satisfies(d.requires) {
            continue;
        }
        for g in &candidates {
            let params = KernelParams {
                geometry: Some(*g),
                ..KernelParams::default()
            };
            let meas = measure_kernel(d.name, m, k, n, s, 42, params, &timer);
            println!(
                "[e2e] geometry {} × {}: {:.2} GFLOP/s ({:.3} flops/cycle, M={m} K={k} N={n} s={s})",
                d.name,
                g.name(),
                meas.gflops(),
                meas.flops_per_cycle(),
            );
            rows.push(Json::obj(vec![
                ("kernel", Json::str(d.name.to_string())),
                ("geometry", Json::str(g.name())),
                ("m", Json::num(m as f64)),
                ("k", Json::num(k as f64)),
                ("n", Json::num(n as f64)),
                ("sparsity", Json::num(s as f64)),
                ("gflops", Json::num(meas.gflops())),
                ("flops_per_cycle", Json::num(meas.flops_per_cycle())),
            ]));
        }
    }
    Json::arr(rows)
}

/// PR 8: two models behind one registry under deliberately skewed load.
/// "hot" carries most of the clients and a small admission queue budget;
/// "cold" idles along beside it. What this measures: the budget capping
/// the hot queue (rejections instead of unbounded latency), the cold
/// model staying responsive, and the demand balancer splitting the shared
/// thread budget toward the hot model.
fn fleet_skewed_load(scale: BenchScale) -> Json {
    let (hot_clients, cold_clients, reqs) = match scale {
        BenchScale::Full => (12usize, 2usize, 150usize),
        BenchScale::Ci => (6, 1, 20),
    };
    let registry = Arc::new(ModelRegistry::with_thread_budget(
        Arc::new(Planner::new()),
        4,
    ));
    // Budget below the hot client count: concurrent submits past it are
    // rejected 429-style rather than queued.
    let hot_cfg = ModelConfig::from_json(
        r#"{"name":"hot","dims":[256,1024,256],"sparsity":0.25,"seed":21,
            "queue_budget":4}"#,
    )
    .unwrap();
    let cold_cfg = ModelConfig::from_json(
        r#"{"name":"cold","dims":[256,1024,256],"sparsity":0.25,"seed":22}"#,
    )
    .unwrap();
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_micros(500),
    };
    for cfg in [&hot_cfg, &cold_cfg] {
        registry
            .load(
                cfg,
                LoadOptions {
                    policy,
                    warm: true,
                    ..LoadOptions::default()
                },
            )
            .unwrap();
    }
    registry.start_balancer(Duration::from_millis(50));
    let router = Arc::new(Router::with_registry(Arc::clone(&registry)));

    let gen = |model: &str, clients: usize, seed: u64| LoadGenerator {
        clients,
        requests_per_client: reqs,
        d_in: 256,
        model: model.into(),
        seed,
        request_timeout: Duration::from_secs(30),
    };
    let cold_gen = gen("cold", cold_clients, 8);
    let router_bg = Arc::clone(&router);
    let cold_thread = std::thread::spawn(move || cold_gen.run_inprocess(&router_bg));
    let hot_report = gen("hot", hot_clients, 7).run_inprocess(&router);
    let cold_report = cold_thread.join().unwrap();

    let model_json = |name: &str, clients: usize, report: &stgemm::coordinator::LoadGenReport| {
        let handle = registry.get(name).unwrap();
        let rejections = handle
            .engine()
            .metrics
            .admission_rejections
            .load(Ordering::Relaxed);
        let attempts = (clients * reqs) as f64;
        println!(
            "[e2e] fleet '{name}': {clients} clients, {:.0} req/s, p50 {} µs, p99 {} µs, \
             {} errors, {rejections} admission rejections ({:.1}%), thread cap {}",
            report.throughput_rps,
            report.latency_us_p50,
            report.latency_us_p99,
            report.errors,
            100.0 * rejections as f64 / attempts,
            handle.thread_cap(),
        );
        Json::obj(vec![
            ("model", Json::str(name.to_string())),
            ("state", Json::str(handle.state().as_str())),
            ("clients", Json::num(clients as f64)),
            ("requests", Json::num(report.total_requests as f64)),
            ("rps", Json::num(report.throughput_rps)),
            ("p50_us", Json::num(report.latency_us_p50 as f64)),
            ("p99_us", Json::num(report.latency_us_p99 as f64)),
            ("errors", Json::num(report.errors as f64)),
            ("admission_rejections", Json::num(rejections as f64)),
            (
                "admission_rejection_rate",
                Json::num(rejections as f64 / attempts),
            ),
            ("queue_budget", Json::num(handle.admission().budget() as f64)),
            ("thread_cap", Json::num(handle.thread_cap() as f64)),
        ])
    };
    let out = Json::obj(vec![
        ("thread_budget", Json::num(registry.thread_budget() as f64)),
        (
            "models",
            Json::arr(vec![
                model_json("hot", hot_clients, &hot_report),
                model_json("cold", cold_clients, &cold_report),
            ]),
        ),
    ]);
    registry.shutdown();
    out
}

/// PR 9: decode-serving scenarios. Each builds a fresh scheduler over
/// the benchmark model (256→1024→256, square as decode requires), starts
/// its step loop, and drives bursty sessions through the in-process
/// client path — the same continuous-batching machinery `/generate`
/// streams through, minus the socket.
fn decode_serving(scale: BenchScale) -> Json {
    let (solo_sessions, concurrent_sessions, mean_tokens) = match scale {
        BenchScale::Full => (4, 8, 64),
        BenchScale::Ci => (2, 4, 8),
    };
    let scenario = |label: &str,
                    capacity: usize,
                    sessions: usize,
                    burst: usize,
                    seed: u64|
     -> Json {
        let cfg = ModelConfig::from_json(
            r#"{"name":"decode","dims":[256,1024,256],"sparsity":0.25,"seed":4321}"#,
        )
        .unwrap();
        let mlp = TernaryMlp::planned(&cfg, &Arc::new(Planner::new())).unwrap();
        let cache = Arc::clone(mlp.plan_cache().expect("config-built"));
        let metrics = Arc::new(Metrics::new());
        let sched = Arc::new(
            DecodeScheduler::new(
                "decode",
                &cache,
                Arc::clone(&metrics),
                DecodeConfig {
                    max_sessions: capacity,
                    default_max_tokens: mean_tokens,
                    ..DecodeConfig::default()
                },
            )
            .unwrap(),
        );
        sched.spawn_loop();
        let gen = DecodeLoadGen {
            sessions,
            burst,
            burst_gap: Duration::from_millis(1),
            d: 256,
            model: "decode".into(),
            seed,
            mean_tokens,
            request_timeout: Duration::from_secs(120),
        };
        let report = gen.run_scheduler(&sched);
        let stats = sched.arena_stats();
        let occupancy = metrics.decode_mean_occupancy();
        sched.shutdown();
        println!("  [decode:{label}] {}", report.summary());
        Json::obj(vec![
            ("scenario", Json::str(label)),
            ("capacity", Json::num(capacity as f64)),
            ("sessions", Json::num(report.sessions as f64)),
            ("tokens", Json::num(report.tokens as f64)),
            ("tokens_per_sec", Json::num(report.tokens_per_sec)),
            ("intertoken_us_p50", Json::num(report.intertoken_us_p50 as f64)),
            ("intertoken_us_p99", Json::num(report.intertoken_us_p99 as f64)),
            ("mean_step_occupancy", Json::num(occupancy)),
            ("arena_allocations", Json::num(stats.allocations as f64)),
            ("arena_reuses", Json::num(stats.reuses as f64)),
            ("errors", Json::num(report.errors as f64)),
        ])
    };
    Json::arr(vec![
        // Capacity 1: every step is the tuned M=1 GEMV path; extra
        // sessions queue at admission and run serially.
        scenario("single_session_m1", 1, solo_sessions, 1, 71),
        // Capacity 4 with bursty arrivals: steps carry whatever mix of
        // sessions is live — continuous batching proper.
        scenario("concurrent_sessions", 4, concurrent_sessions, 4, 72),
    ])
}

/// Pinned vs unpinned serving on the *same* model: a wavefront forward
/// (GFLOP/s + per-layer stall) and a decode run (tokens/sec), once with
/// the pool placed on performance cores and once left to the OS
/// (`--no-pin`). Outputs are bitwise-identical by construction
/// (`tests/placement.rs`); what this measures is the wall/stall delta —
/// and `pinned_workers` records whether the OS actually honored the pins
/// (CI containers may refuse them, making the regimes equivalent).
fn placement_pinned_vs_unpinned(scale: BenchScale) -> Json {
    let reps = match scale {
        BenchScale::Full => 50,
        BenchScale::Ci => 5,
    };
    let (m, threads, dims) = (64usize, 4usize, [256usize, 1024, 512, 256]);
    let forward = |policy: PlacementPolicy| -> Json {
        let cfg = ModelConfig::from_json(&format!(
            r#"{{"name":"placed","dims":[256,1024,512,256],"sparsity":0.25,
                "seed":99,"threads":{threads}}}"#
        ))
        .unwrap();
        let planner = Planner::new().with_topology(CpuTopology::host().clone());
        planner.set_placement(policy);
        let mlp = TernaryMlp::planned(&cfg, &Arc::new(planner)).unwrap();
        let cache = mlp.plan_cache().expect("config-built model");
        let plan = cache.compile_pipeline(m, PipelineMode::Wavefront).unwrap();
        let x = Matrix::random(m, dims[0], 5);
        let mut y = Matrix::zeros(m, dims[dims.len() - 1]);
        plan.run(&x, &mut y).expect("warmup");
        let mut agg = ModeAggregate::default();
        let mut pinned_workers = 0usize;
        for _ in 0..reps {
            let stats = plan.run(&x, &mut y).expect("pipeline run");
            pinned_workers = pinned_workers.max(stats.pinned_workers);
            agg.absorb(&stats);
        }
        let flops_per_run: f64 = dims
            .windows(2)
            .map(|kn| cost_flops(m, kn[0], kn[1], 0.25))
            .sum();
        let gflops = if agg.wall_us > 0 {
            flops_per_run * reps as f64 / (agg.wall_us as f64 * 1e3)
        } else {
            0.0
        };
        println!(
            "  [placement:{policy}] forward wall {} µs / {reps} reps, \
             stall {} µs, {gflops:.2} GFLOP/s, {pinned_workers} pinned",
            agg.wall_us, agg.stall_us
        );
        Json::obj(vec![
            ("policy", Json::str(policy.as_str())),
            ("gflops", Json::num(gflops)),
            ("pinned_workers", Json::num(pinned_workers as f64)),
            ("forward", agg.json()),
        ])
    };
    let decode = |policy: PlacementPolicy| -> Json {
        let cfg = ModelConfig::from_json(
            r#"{"name":"placed_dec","dims":[256,1024,256],"sparsity":0.25,"seed":4321}"#,
        )
        .unwrap();
        let planner = Planner::new().with_topology(CpuTopology::host().clone());
        planner.set_placement(policy);
        let mlp = TernaryMlp::planned(&cfg, &Arc::new(planner)).unwrap();
        let cache = Arc::clone(mlp.plan_cache().expect("config-built"));
        let metrics = Arc::new(Metrics::new());
        let sched = Arc::new(
            DecodeScheduler::new(
                "placed_dec",
                &cache,
                Arc::clone(&metrics),
                DecodeConfig {
                    max_sessions: 4,
                    default_max_tokens: 16,
                    placement: match policy {
                        PlacementPolicy::None => PlacementPolicy::None,
                        _ => PlacementPolicy::Compact,
                    },
                },
            )
            .unwrap(),
        );
        sched.spawn_loop();
        let gen = DecodeLoadGen {
            sessions: match scale {
                BenchScale::Full => 8,
                BenchScale::Ci => 4,
            },
            burst: 4,
            burst_gap: Duration::from_millis(1),
            d: 256,
            model: "placed_dec".into(),
            seed: 73,
            mean_tokens: 16,
            request_timeout: Duration::from_secs(120),
        };
        let report = gen.run_scheduler(&sched);
        let tick = sched.tick_placement();
        sched.shutdown();
        println!("  [placement:{policy}] decode {}", report.summary());
        Json::obj(vec![
            ("policy", Json::str(policy.as_str())),
            ("tokens_per_sec", Json::num(report.tokens_per_sec)),
            ("intertoken_us_p50", Json::num(report.intertoken_us_p50 as f64)),
            ("intertoken_us_p99", Json::num(report.intertoken_us_p99 as f64)),
            (
                "tick_pin",
                tick.map(|(_, outcome)| Json::str(outcome.as_str()))
                    .unwrap_or(Json::Null),
            ),
            ("errors", Json::num(report.errors as f64)),
        ])
    };
    Json::obj(vec![
        ("m", Json::num(m as f64)),
        ("threads", Json::num(threads as f64)),
        ("reps", Json::num(reps as f64)),
        ("topology", Json::str(CpuTopology::host().describe())),
        (
            "forward",
            Json::arr(vec![
                forward(PlacementPolicy::PerfCoresFirst),
                forward(PlacementPolicy::None),
            ]),
        ),
        (
            "decode",
            Json::arr(vec![
                decode(PlacementPolicy::PerfCoresFirst),
                decode(PlacementPolicy::None),
            ]),
        ),
    ])
}

fn main() {
    let scale = BenchScale::from_env();
    let (clients, reqs, stall_reps) = match scale {
        BenchScale::Full => (16, 200, 50),
        BenchScale::Ci => (4, 25, 5),
    };
    let mut table = Table::new(
        format!("E2E serving: ternary FFN 256→1024→256, {clients} clients × {reqs} reqs"),
        &[
            "backend",
            "requests",
            "req/s",
            "p50 µs",
            "p95 µs",
            "p99 µs",
            "mean batch",
            "errors",
        ],
    );
    let mut rows: Vec<ServingRow> = Vec::new();

    // Native backend on the synthetic config, through the serving path
    // proper: planner-selected kernels, M-bucketed plan cache, wavefront
    // pipelining (the default).
    let cfg = ModelConfig::from_json(
        r#"{"name":"native","dims":[256,1024,256],"sparsity":0.25,"seed":4321}"#,
    )
    .unwrap();
    let engine = Engine::from_config(&cfg, &Arc::new(Planner::new())).unwrap();
    rows.push(bench_backend("native", engine, clients, reqs));

    // Same model with the per-layer barrier path (`--no-pipeline`): the
    // serving-level cost of the inter-layer joins the wavefront removes.
    let cfg_barrier = ModelConfig::from_json(
        r#"{"name":"native_barrier","dims":[256,1024,256],"sparsity":0.25,"seed":4321,
            "pipeline":false}"#,
    )
    .unwrap();
    let engine = Engine::from_config(&cfg_barrier, &Arc::new(Planner::new())).unwrap();
    rows.push(bench_backend("native_barrier", engine, clients, reqs));

    // Also native with the baseline kernel — the explicit-override escape
    // hatch (config `kernel` key), kept to show what the paper's
    // optimizations buy at the serving level.
    let cfg_base = ModelConfig::from_json(
        r#"{"name":"native_base","dims":[256,1024,256],"sparsity":0.25,"seed":4321,
            "kernel":"base_tcsc"}"#,
    )
    .unwrap();
    let engine = Engine::from_config(&cfg_base, &Arc::new(Planner::new())).unwrap();
    rows.push(bench_backend("native_base", engine, clients, reqs));

    // XLA backend from the real artifact (identical weights via manifest).
    match Manifest::load("artifacts") {
        Ok(manifest) if !manifest.variants_of("ffn_e2e").is_empty() => {
            let planner = Planner::new();
            let hints = PlanHints {
                expected_batch: 8,
                ..Default::default()
            };
            let v0 = manifest.variants_of("ffn_e2e")[0];
            let mut layers = Vec::new();
            for (i, l) in v0.layers.iter().enumerate() {
                let w = v0.load_weights(&manifest.dir, i).expect("weights");
                let b = v0.load_bias(&manifest.dir, i).expect("bias");
                layers.push(
                    TernaryLinear::planned(&planner, &w, b, 1.0, l.prelu_alpha, &hints)
                        .unwrap(),
                );
            }
            let mlp = TernaryMlp::from_layers("xla".into(), layers).unwrap();
            let xla = XlaExecutor::spawn(&manifest, "ffn_e2e").expect("xla");
            let engine = Engine::new("xla", mlp)
                .with_xla(xla)
                .with_backend(Backend::Xla);
            rows.push(bench_backend("xla", engine, clients, reqs));
        }
        _ => eprintln!("[e2e] artifacts not found — skipping XLA backend row"),
    }

    for row in &rows {
        table.row(row.table_row());
    }
    println!("{}", table.render());
    if let Ok(p) = write_csv(&table, "e2e_serving.csv") {
        println!("  [csv] {}", p.display());
    }

    // Scheduler-level barrier vs wavefront with per-layer stall, then the
    // whole report as JSON for cross-PR tracking.
    let stall = barrier_vs_wavefront(stall_reps);
    let report = Json::obj(vec![
        ("bench", Json::str("e2e_serving")),
        ("clients", Json::num(clients as f64)),
        ("requests_per_client", Json::num(reqs as f64)),
        ("serving", Json::arr(rows.iter().map(ServingRow::json))),
        ("barrier_vs_wavefront", stall),
    ]);
    match std::fs::write("e2e_serving.json", report.encode_pretty()) {
        Ok(()) => println!("  [json] e2e_serving.json"),
        Err(e) => eprintln!("  [json] write failed: {e}"),
    }

    // PR 7 tracking artifact: per-family GFLOP/s (capability-selected
    // representatives) and per-geometry GFLOP/s (cache-derived candidates
    // on the geometry-axis kernels) plus the serving latency rows, at the
    // repo root so cross-PR tooling finds it without knowing the crate
    // layout.
    let families = family_gflops(scale);
    let geometries = geometry_gflops(scale);
    let pr7 = Json::obj(vec![
        ("bench", Json::str("pr7_blocking_geometry")),
        (
            "serving",
            Json::arr(rows.iter().map(|r| {
                Json::obj(vec![
                    ("backend", Json::str(r.backend.clone())),
                    ("p50_us", Json::num(r.p50_us as f64)),
                    ("p99_us", Json::num(r.p99_us as f64)),
                    ("rps", Json::num(r.rps)),
                ])
            })),
        ),
        ("kernel_families", families),
        ("kernel_geometries", geometries),
    ]);
    let pr7_path = match std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
        Some(root) => root.join("BENCH_pr7.json"),
        None => std::path::PathBuf::from("BENCH_pr7.json"),
    };
    match std::fs::write(&pr7_path, pr7.encode_pretty()) {
        Ok(()) => println!("  [json] {}", pr7_path.display()),
        Err(e) => eprintln!("  [json] {} write failed: {e}", pr7_path.display()),
    }

    // PR 8 tracking artifact: the two-model skewed-load fleet scenario —
    // per-model throughput/latency, the hot model's admission-rejection
    // rate, and the balancer's thread split — at the repo root alongside
    // BENCH_pr7.json.
    let fleet = fleet_skewed_load(scale);
    let pr8 = Json::obj(vec![
        ("bench", Json::str("pr8_fleet_registry")),
        ("scale", Json::str(format!("{scale:?}"))),
        ("fleet_skewed_load", fleet),
    ]);
    let pr8_path = match std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
        Some(root) => root.join("BENCH_pr8.json"),
        None => std::path::PathBuf::from("BENCH_pr8.json"),
    };
    match std::fs::write(&pr8_path, pr8.encode_pretty()) {
        Ok(()) => println!("  [json] {}", pr8_path.display()),
        Err(e) => eprintln!("  [json] {} write failed: {e}", pr8_path.display()),
    }

    // PR 9 tracking artifact: the decode-serving scenarios — tokens/sec
    // and inter-token p50/p99 for the single-session M=1 path and for
    // concurrent continuously-batched sessions.
    let decode = decode_serving(scale);
    let pr9 = Json::obj(vec![
        ("bench", Json::str("pr9_decode_serving")),
        ("scale", Json::str(format!("{scale:?}"))),
        ("decode_serving", decode),
    ]);
    let pr9_path = match std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
        Some(root) => root.join("BENCH_pr9.json"),
        None => std::path::PathBuf::from("BENCH_pr9.json"),
    };
    match std::fs::write(&pr9_path, pr9.encode_pretty()) {
        Ok(()) => println!("  [json] {}", pr9_path.display()),
        Err(e) => eprintln!("  [json] {} write failed: {e}", pr9_path.display()),
    }

    // PR 10 tracking artifact: pinned vs unpinned serving — forward
    // GFLOP/s with per-layer stall and decode tokens/sec under the
    // performance-core placement vs the OS scheduler.
    let placement = placement_pinned_vs_unpinned(scale);
    let pr10 = Json::obj(vec![
        ("bench", Json::str("pr10_worker_placement")),
        ("scale", Json::str(format!("{scale:?}"))),
        ("pinned_vs_unpinned", placement),
    ]);
    let pr10_path = match std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
        Some(root) => root.join("BENCH_pr10.json"),
        None => std::path::PathBuf::from("BENCH_pr10.json"),
    };
    match std::fs::write(&pr10_path, pr10.encode_pretty()) {
        Ok(()) => println!("  [json] {}", pr10_path.display()),
        Err(e) => eprintln!("  [json] {} write failed: {e}", pr10_path.display()),
    }
}
