//! Ablations the paper describes and *rejects* — reproduced to confirm the
//! negative results: value compression (wins only at 50% density) and the
//! inverted index (decode branch makes it slower than base), plus the
//! headline speedup numbers.

use stgemm::bench::figures::{ablation_compressed, ablation_inverted, headline};
use stgemm::bench::harness::BenchScale;
use stgemm::bench::report::write_csv;

fn main() {
    let scale = BenchScale::from_env();
    for (table, file) in [
        (headline(scale), "headline.csv"),
        (ablation_compressed(scale), "ablation_compressed.csv"),
        (ablation_inverted(scale), "ablation_inverted.csv"),
    ] {
        println!("{}", table.render());
        if let Ok(p) = write_csv(&table, file) {
            println!("  [csv] {}\n", p.display());
        }
    }
}
