//! Paper Fig 6: scalar kernel variants over K at 50% sparsity
//! (flops/cycle; paper M=64, N=4096).

use stgemm::bench::figures::fig6_variants;
use stgemm::bench::harness::BenchScale;
use stgemm::bench::report::write_csv;

fn main() {
    let table = fig6_variants(BenchScale::from_env());
    println!("{}", table.render());
    if let Ok(p) = write_csv(&table, "fig6_variants.csv") {
        println!("  [csv] {}", p.display());
    }
}
