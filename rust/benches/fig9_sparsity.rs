//! Paper Fig 9: the best scalar kernel across sparsity levels × K
//! (M=64, N=4096, B=min(K,4096)) plus the baseline — the headline
//! stability-across-K result.

use stgemm::bench::figures::fig9_sparsity;
use stgemm::bench::harness::BenchScale;
use stgemm::bench::report::write_csv;

fn main() {
    let table = fig9_sparsity(BenchScale::from_env());
    println!("{}", table.render());
    if let Ok(p) = write_csv(&table, "fig9_sparsity.csv") {
        println!("  [csv] {}", p.display());
    }
}
