//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The build environment cannot fetch or link the native `xla_extension`
//! bindings, so this crate mirrors the API surface `stgemm::runtime` uses
//! and gates it at **runtime**: client creation succeeds (so the serving
//! stack builds and its native path is fully testable), while anything that
//! would actually need the PJRT runtime — HLO parsing, compilation,
//! execution — returns a clear error. Swap this path dependency for the
//! real bindings in `rust/Cargo.toml` to light up the XLA backend.

use anyhow::{anyhow, Result};

fn unavailable() -> anyhow::Error {
    anyhow!(
        "xla runtime unavailable: this build links the offline stub \
         (rust/vendor/xla); substitute the real `xla` bindings in \
         rust/Cargo.toml to execute PJRT artifacts"
    )
}

/// Stub PJRT client: constructible, cannot compile.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (offline xla shim)".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub HLO module handle.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Reads the file (so missing-artifact errors stay precise), then
    /// reports that parsing needs the real runtime.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path).map_err(|e| anyhow!("read {path}: {e}"))?;
        Err(unavailable())
    }
}

/// Stub computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub literal.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _priv: () })
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_cannot_compile() {
        let client = PjRtClient::cpu().unwrap();
        assert!(!client.platform_name().is_empty());
        let proto_err = HloModuleProto::from_text_file("/nope.hlo.txt").unwrap_err();
        assert!(format!("{proto_err}").contains("read /nope.hlo.txt"));
    }
}
