//! Offline shim of the `anyhow` crate: the API subset this repository uses
//! (`Result`, `Error`, `Context`, `anyhow!`, `ensure!`, `bail!`) backed by a
//! plain string. The build environment has no network access, so the real
//! crate cannot be fetched; swapping this out is a one-line change in
//! `rust/Cargo.toml` when a registry is available.

use std::fmt;

/// String-backed error. Context is prepended `"context: cause"` so the
/// rendered message matches the real crate's `{:#}` alternate format.
#[derive(Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both render the full context chain.
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/here").context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains_and_renders() {
        let e = io_fail().unwrap_err();
        let rendered = format!("{e:#}");
        assert!(rendered.starts_with("reading config:"), "{rendered}");
        assert_eq!(format!("{e}"), rendered);
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn macros() {
        let name = "x";
        let e = anyhow!("bad {name}");
        assert_eq!(format!("{e}"), "bad x");
        let e2 = anyhow!(String::from("owned"));
        assert_eq!(format!("{e2}"), "owned");
        let f = |ok: bool| -> Result<u8> {
            ensure!(ok, "must be ok, got {}", ok);
            Ok(1)
        };
        assert!(f(true).is_ok());
        assert!(f(false).is_err());
    }
}
