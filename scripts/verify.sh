#!/usr/bin/env bash
# Offline CI gate: build, test, format, lint. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Release-mode smoke: optimized timing shifts the wavefront scheduler's
# interleavings, so races masked by debug-build slowness surface here.
echo "== cargo test --release -q =="
cargo test --release -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== cargo doc --no-deps (warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "verify: OK"
