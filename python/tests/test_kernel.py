"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, sparsities and dtypes; every property asserts
allclose against ``ref.py``.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import ternary_gemm as tk
from compile import model as M


def make_case(m, k, n, sparsity, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, size=(m, k)).astype(np.float32)
    w = M.generate_ternary(k, n, sparsity, seed)
    b = rng.uniform(-0.5, 0.5, size=n).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)


# ---------------------------------------------------------------- signsplit

class TestSignSplitKernel:
    @pytest.mark.parametrize("sparsity", [0.5, 0.25, 0.125, 0.0625])
    def test_matches_ref_paper_sparsities(self, sparsity):
        x, w, b = make_case(8, 128, 64, sparsity, 42)
        got = tk.ternary_gemm(x, w, b, bm=4, bk=32, bn=16)
        want = ref.ternary_gemm_ref(x, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_signsplit_ref_equals_plain_ref(self):
        x, w, b = make_case(4, 64, 32, 0.5, 7)
        np.testing.assert_allclose(
            ref.ternary_gemm_signsplit_ref(x, w, b),
            ref.ternary_gemm_ref(x, w, b),
            rtol=1e-5,
            atol=1e-6,
        )

    def test_single_tile(self):
        x, w, b = make_case(2, 16, 8, 0.5, 3)
        got = tk.ternary_gemm(x, w, b, bm=2, bk=16, bn=8)
        np.testing.assert_allclose(got, ref.ternary_gemm_ref(x, w, b), rtol=1e-5, atol=1e-5)

    def test_multi_k_step_accumulation(self):
        # K split over 8 grid steps exercises the accumulate path.
        x, w, b = make_case(4, 256, 16, 0.25, 11)
        got = tk.ternary_gemm(x, w, b, bm=4, bk=32, bn=16)
        np.testing.assert_allclose(got, ref.ternary_gemm_ref(x, w, b), rtol=1e-5, atol=1e-5)

    def test_all_zero_weights_give_bias(self):
        x, _, b = make_case(3, 32, 8, 0.5, 5)
        w = jnp.zeros((32, 8), jnp.int8)
        got = tk.ternary_gemm(x, w, b, bm=3, bk=32, bn=8)
        np.testing.assert_allclose(got, jnp.broadcast_to(b, (3, 8)), rtol=1e-6, atol=1e-6)

    def test_rejects_bad_tiling(self):
        x, w, b = make_case(5, 33, 7, 0.5, 1)
        with pytest.raises(AssertionError):
            tk.ternary_gemm(x, w, b, bm=2, bk=32, bn=4)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([1, 2, 4, 8]),
        k=st.sampled_from([16, 32, 64, 128]),
        n=st.sampled_from([8, 16, 32]),
        sparsity=st.sampled_from([0.5, 0.25, 0.125, 0.0625, 0.0, 1.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, m, k, n, sparsity, seed):
        x, w, b = make_case(m, k, n, sparsity, seed)
        bm, bk, bn = M.pick_tiles(m, k, n)
        got = tk.ternary_gemm(x, w, b, bm=bm, bk=bk, bn=bn)
        want = ref.ternary_gemm_ref(x, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(dtype=st.sampled_from([np.float32, np.float16]))
    def test_dtype_sweep(self, dtype):
        x, w, b = make_case(4, 64, 16, 0.5, 9)
        x = x.astype(dtype)
        got = tk.ternary_gemm(x.astype(jnp.float32), w, b, bm=4, bk=32, bn=16)
        want = ref.ternary_gemm_ref(x.astype(jnp.float32), w, b)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ gather

class TestGatherKernel:
    @pytest.mark.parametrize("sparsity", [0.5, 0.25, 0.0625])
    def test_matches_ref(self, sparsity):
        x, w, b = make_case(4, 64, 32, sparsity, 21)
        pos, neg, _ = tk.pack_padded_indices(w)
        xp = tk.pad_activations(x)
        got = tk.ternary_gemm_gather(xp, pos, neg, b, bn=16)
        want = ref.ternary_gemm_ref(x, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_gather_ref_agrees_with_dense_ref(self):
        x, w, b = make_case(3, 32, 16, 0.5, 31)
        pos, neg, _ = tk.pack_padded_indices(w)
        xp = tk.pad_activations(x)
        np.testing.assert_allclose(
            ref.padded_gather_ref(xp, pos, neg, b),
            ref.ternary_gemm_ref(x, w, b),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_dummy_column_is_zero(self):
        x = jnp.ones((2, 8), jnp.float32)
        xp = tk.pad_activations(x)
        assert xp.shape == (2, 9)
        assert np.all(np.asarray(xp[:, -1]) == 0.0)

    def test_pad_multiple(self):
        _, w, _ = make_case(1, 32, 8, 0.5, 4)
        pos, neg, p = tk.pack_padded_indices(w, pad_multiple=4)
        assert p % 4 == 0
        assert pos.shape == (8, p) and neg.shape == (8, p)

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.sampled_from([1, 3, 8]),
        k=st.sampled_from([8, 32, 64]),
        n=st.sampled_from([4, 8, 16]),
        sparsity=st.sampled_from([0.5, 0.25, 0.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, m, k, n, sparsity, seed):
        x, w, b = make_case(m, k, n, sparsity, seed)
        pos, neg, _ = tk.pack_padded_indices(w)
        xp = tk.pad_activations(x)
        got = tk.ternary_gemm_gather(xp, pos, neg, b, bn=n)
        want = ref.ternary_gemm_ref(x, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- prelu

class TestPrelu:
    def test_matches_ref(self):
        y = jnp.asarray(np.random.default_rng(2).normal(size=(4, 8)).astype(np.float32))
        np.testing.assert_allclose(
            tk.prelu(y, 0.25), ref.prelu_ref(y, 0.25), rtol=1e-6
        )

    def test_alpha_zero_is_relu(self):
        y = jnp.asarray([[-1.0, 2.0]])
        np.testing.assert_allclose(tk.prelu(y, 0.0), [[0.0, 2.0]])


# -------------------------------------------------------------- vmem model

class TestVmemModel:
    def test_default_tiles_fit_budget(self):
        assert tk.vmem_bytes_per_step(tk.DEFAULT_BM, tk.DEFAULT_BK, tk.DEFAULT_BN) < 8 * 2**20

    def test_monotone_in_bk(self):
        assert tk.vmem_bytes_per_step(8, 512, 128) > tk.vmem_bytes_per_step(8, 256, 128)
