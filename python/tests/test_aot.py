"""AOT pipeline tests: HLO text lowering, artifact emission, manifest."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def tiny_weights():
    spec = M.ffn_spec("aot_test", batch=2, dims=[16, 32, 8], sparsity=0.25, seed=5)
    return M.ModelWeights.generate(spec)


class TestLowering:
    def test_hlo_text_structure(self, tiny_weights):
        hlo = M.lower_to_hlo_text(tiny_weights)
        assert "ENTRY" in hlo
        assert "HloModule" in hlo
        # Input parameter shape appears.
        assert "f32[2,16]" in hlo

    def test_hlo_is_deterministic(self, tiny_weights):
        assert M.lower_to_hlo_text(tiny_weights) == M.lower_to_hlo_text(tiny_weights)


class TestEmission:
    def test_emit_variant_files(self, tiny_weights, tmp_path):
        entry = aot.emit_variant(tiny_weights, str(tmp_path))
        assert entry["batch"] == 2
        assert entry["d_in"] == 16 and entry["d_out"] == 8
        for layer in entry["layers"]:
            w = np.fromfile(tmp_path / layer["weights_file"], dtype=np.int8)
            assert w.size == layer["k"] * layer["n"]
            assert layer["nnz"] == int(np.count_nonzero(w))
            b = np.fromfile(tmp_path / layer["bias_file"], dtype="<f4")
            assert b.size == layer["n"]
        assert (tmp_path / entry["hlo_file"]).exists()

    def test_probe_consistency(self, tiny_weights, tmp_path):
        import jax.numpy as jnp

        entry = aot.emit_variant(tiny_weights, str(tmp_path))
        x = np.fromfile(tmp_path / entry["probe_x_file"], dtype="<f4").reshape(
            entry["batch"], entry["d_in"]
        )
        y = np.fromfile(tmp_path / entry["probe_y_file"], dtype="<f4").reshape(
            entry["batch"], entry["d_out"]
        )
        want = np.asarray(M.forward_ref(tiny_weights, jnp.asarray(x)))
        np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)

    def test_main_writes_manifest(self, tmp_path):
        rc = aot.main(["--out", str(tmp_path), "--only", "ffn_tiny_b1"])
        assert rc == 0
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["version"] == 1
        names = [m["name"] for m in manifest["models"]]
        assert names == ["ffn_tiny_b1"]

    def test_main_rejects_unknown_variant(self, tmp_path):
        assert aot.main(["--out", str(tmp_path), "--only", "nope"]) == 2
