"""Layer-2 correctness: FFN model forward (Pallas path) vs jnp oracle,
weight generation invariants, tile picking."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M


class TestWeightGeneration:
    @pytest.mark.parametrize("sparsity", [0.5, 0.25, 0.125, 0.0625])
    def test_exact_nnz(self, sparsity):
        w = M.generate_ternary(64, 32, sparsity, 5)
        assert np.count_nonzero(w) == round(sparsity * 64 * 32)
        assert set(np.unique(w)).issubset({-1, 0, 1})

    def test_deterministic(self):
        a = M.generate_ternary(32, 32, 0.25, 9)
        b = M.generate_ternary(32, 32, 0.25, 9)
        c = M.generate_ternary(32, 32, 0.25, 10)
        assert np.array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_balanced_signs(self):
        w = M.generate_ternary(100, 100, 0.5, 3)
        pos = int((w == 1).sum())
        neg = int((w == -1).sum())
        assert abs(pos - neg) <= 1

    @settings(max_examples=20, deadline=None)
    @given(
        k=st.integers(4, 128),
        n=st.integers(4, 64),
        sparsity=st.sampled_from([0.0, 0.0625, 0.25, 0.5, 1.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_nnz_invariant(self, k, n, sparsity, seed):
        w = M.generate_ternary(k, n, sparsity, seed)
        assert np.count_nonzero(w) == round(sparsity * k * n)


class TestTilePicker:
    def test_divides_shapes(self):
        for m, k, n in [(1, 64, 128), (8, 256, 1024), (3, 33, 7), (5, 100, 30)]:
            bm, bk, bn = M.pick_tiles(m, k, n)
            assert m % bm == 0 and k % bk == 0 and n % bn == 0

    def test_respects_vmem_budget(self):
        from compile.kernels import ternary_gemm as tk

        bm, bk, bn = M.pick_tiles(8, 16384, 4096)
        assert tk.vmem_bytes_per_step(bm, bk, bn) <= 8 * 2**20


class TestModelForward:
    def _spec(self, batch=4, dims=(32, 64, 16), sparsity=0.25, seed=77):
        return M.ffn_spec("t", batch, list(dims), sparsity, seed)

    def test_pallas_matches_ref(self):
        spec = self._spec()
        weights = M.ModelWeights.generate(spec)
        x = jnp.asarray(
            np.random.default_rng(1).uniform(-1, 1, (spec.batch, spec.d_in)).astype(np.float32)
        )
        got = M.forward(weights, x)
        want = M.forward_ref(weights, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_output_shape(self):
        spec = self._spec(batch=2, dims=(16, 32, 8))
        weights = M.ModelWeights.generate(spec)
        x = jnp.zeros((2, 16), jnp.float32)
        y = M.forward(weights, x)
        assert y.shape == (2, 8)

    def test_prelu_only_between_layers(self):
        spec = self._spec(dims=(16, 32, 8))
        assert spec.layers[0].prelu_alpha is not None
        assert spec.layers[-1].prelu_alpha is None

    def test_deeper_stack(self):
        spec = self._spec(batch=2, dims=(16, 32, 32, 8))
        weights = M.ModelWeights.generate(spec)
        x = jnp.asarray(
            np.random.default_rng(3).uniform(-1, 1, (2, 16)).astype(np.float32)
        )
        np.testing.assert_allclose(
            M.forward(weights, x), M.forward_ref(weights, x), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=8, deadline=None)
    @given(
        batch=st.sampled_from([1, 2, 8]),
        sparsity=st.sampled_from([0.5, 0.25, 0.0625]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_model_sweep(self, batch, sparsity, seed):
        spec = self._spec(batch=batch, sparsity=sparsity, seed=seed)
        weights = M.ModelWeights.generate(spec)
        x = jnp.asarray(
            np.random.default_rng(seed).uniform(-1, 1, (batch, spec.d_in)).astype(np.float32)
        )
        np.testing.assert_allclose(
            M.forward(weights, x), M.forward_ref(weights, x), rtol=1e-4, atol=1e-4
        )
