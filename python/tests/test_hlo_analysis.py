"""L2 §Perf invariants: the lowered artifacts have the expected HLO
structure (one entry, one sign-split dot pair per layer, no custom-calls,
weights constant-folded exactly once)."""

import numpy as np
import pytest

from compile import hlo_analysis as H
from compile import model as M


@pytest.fixture(scope="module")
def tiny_hlo():
    spec = M.ffn_spec("hlo_t", batch=2, dims=[16, 32, 8], sparsity=0.25, seed=3)
    weights = M.ModelWeights.generate(spec)
    return M.lower_to_hlo_text(weights), spec


class TestAnalyze:
    def test_single_entry(self, tiny_hlo):
        text, _ = tiny_hlo
        stats = H.analyze(text)
        assert stats.entry_count == 1

    def test_sign_split_dot_pair_per_layer(self, tiny_hlo):
        text, spec = tiny_hlo
        stats = H.analyze(text)
        assert stats.dot_count == 2 * len(spec.layers)

    def test_no_custom_calls(self, tiny_hlo):
        text, _ = tiny_hlo
        assert H.analyze(text).custom_call_count == 0

    def test_constants_cover_weights_without_duplication(self, tiny_hlo):
        text, spec = tiny_hlo
        stats = H.analyze(text)
        # Two s8 masks per layer + f32 bias per layer, at minimum.
        min_bytes = sum(2 * l.k * l.n + 4 * l.n for l in spec.layers)
        assert stats.constant_bytes >= min_bytes
        # No gross duplication (allow 3x for layout/padding constants).
        assert stats.constant_bytes < 4 * min_bytes, stats.summary()

    def test_check_artifact_clean(self, tiny_hlo):
        text, spec = tiny_hlo
        assert H.check_artifact(text, len(spec.layers)) == []

    def test_check_artifact_flags_problems(self):
        fake = "ENTRY main {\n  a = f32[2,2]{1,0} dot(x, y)\n}\n"
        problems = H.check_artifact(fake, num_layers=2)
        assert any("dots" in p for p in problems)

    def test_shape_bytes(self):
        assert H._shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4
        assert H._shape_bytes("s8[10]") == 10
        assert H._shape_bytes("pred[]") == 1


class TestRealArtifacts:
    def test_all_artifacts_pass_invariants(self):
        import json, os

        art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        manifest_path = os.path.join(art, "manifest.json")
        if not os.path.exists(manifest_path):
            pytest.skip("artifacts not built")
        with open(manifest_path) as f:
            manifest = json.load(f)
        assert manifest["models"], "manifest has no models"
        for model in manifest["models"]:
            with open(os.path.join(art, model["hlo_file"])) as f:
                text = f.read()
            problems = H.check_artifact(text, len(model["layers"]))
            assert problems == [], f"{model['name']}: {problems}"

    def test_weights_not_elided(self):
        """The print_large_constants regression guard: a weights-sized
        constant must appear with real digits, not `{...}`."""
        import json, os

        art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        manifest_path = os.path.join(art, "manifest.json")
        if not os.path.exists(manifest_path):
            pytest.skip("artifacts not built")
        with open(manifest_path) as f:
            manifest = json.load(f)
        model = manifest["models"][0]
        with open(os.path.join(art, model["hlo_file"])) as f:
            text = f.read()
        assert "constant({...})" not in text, "large constants were elided!"
