"""HLO cost analysis for the AOT artifacts (Layer-2 §Perf verification).

Parses HLO text (the artifact interchange format) and reports op counts,
dot/fusion structure and constant byte volume — the checks behind the
DESIGN.md §7 L2 targets:

* one ENTRY computation per artifact;
* exactly 2 dots per ternary layer (the sign-split pair) — no redundant
  recomputation of either mask matmul;
* no TPU-only custom-calls (the module must run on the CPU PJRT client);
* constant bytes ≈ the weight masks it should embed (detects accidental
  duplication of constant-folded weights).
"""

import re
from collections import Counter
from dataclasses import dataclass


@dataclass
class HloStats:
    entry_count: int
    op_counts: Counter
    dot_count: int
    custom_call_count: int
    constant_bytes: int
    while_count: int

    def summary(self) -> str:
        top = ", ".join(f"{op}:{n}" for op, n in self.op_counts.most_common(8))
        return (
            f"entries={self.entry_count} dots={self.dot_count} "
            f"custom_calls={self.custom_call_count} whiles={self.while_count} "
            f"const_bytes={self.constant_bytes} | {top}"
        )


_SHAPE_RE = re.compile(r"\b[a-z]+\d*\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?[%\w.\-]+\s*=\s*\S+\s+([a-z\-]+)\(")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}


def _shape_bytes(typestr: str) -> int:
    """Bytes of an HLO shape string like ``f32[64,128]{1,0}``."""
    m = re.match(r"([a-z]+\d*)\[([\d,]*)\]", typestr)
    if not m:
        return 0
    dtype, dims = m.groups()
    elems = 1
    for d in dims.split(","):
        if d:
            elems *= int(d)
    return elems * _DTYPE_BYTES.get(dtype, 4)


def analyze(hlo_text: str) -> HloStats:
    entry_count = len(re.findall(r"^ENTRY\b", hlo_text, re.MULTILINE))
    op_counts: Counter = Counter()
    constant_bytes = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(1)
        op_counts[op] += 1
        if op == "constant":
            # type is the token right after '='
            type_m = re.search(r"=\s*(\S+)\s+constant", line)
            if type_m:
                constant_bytes += _shape_bytes(type_m.group(1))
    return HloStats(
        entry_count=entry_count,
        op_counts=op_counts,
        dot_count=op_counts.get("dot", 0),
        custom_call_count=op_counts.get("custom-call", 0),
        constant_bytes=constant_bytes,
        while_count=op_counts.get("while", 0),
    )


def check_artifact(hlo_text: str, num_layers: int) -> list:
    """Return a list of violated L2 invariants (empty = all good)."""
    stats = analyze(hlo_text)
    problems = []
    if stats.entry_count != 1:
        problems.append(f"expected 1 ENTRY, found {stats.entry_count}")
    expected_dots = 2 * num_layers  # sign-split pair per layer
    if stats.dot_count != expected_dots:
        problems.append(
            f"expected {expected_dots} dots (2 per layer), found {stats.dot_count}"
        )
    if stats.custom_call_count:
        problems.append(
            f"{stats.custom_call_count} custom-calls present (not CPU-PJRT-safe)"
        )
    return problems


def main():
    import argparse
    import json as _json
    import os

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    with open(os.path.join(args.artifacts, "manifest.json")) as f:
        manifest = _json.load(f)
    bad = 0
    for model in manifest["models"]:
        with open(os.path.join(args.artifacts, model["hlo_file"])) as f:
            text = f.read()
        stats = analyze(text)
        problems = check_artifact(text, len(model["layers"]))
        status = "OK" if not problems else "FAIL: " + "; ".join(problems)
        print(f"{model['name']}: {stats.summary()} -> {status}")
        bad += bool(problems)
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
