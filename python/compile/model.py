"""Layer-2 JAX model: ternary-quantized FFN built on the Pallas kernels.

The serving workload the paper motivates (quantized-LLM inference) is a
stack of ternary linear layers with PReLU between them — the BitNet-style
FFN block ``Y = (PReLU(X·W1 + b1))·W2 + b2`` with W ternary and a
per-tensor dequantization scale folded into the bias path.

Weights are generated deterministically from a seed with *exact* sparsity
(the same scheme as the Rust ``TernaryMatrix::random``) so the Rust native
path and the AOT artifact can be cross-checked on identical models; the
AOT driver also exports the raw weight bytes for the Rust side to load.
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import ternary_gemm as tk


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One ternary linear layer."""

    k: int
    n: int
    sparsity: float
    seed: int
    scale: float = 1.0
    prelu_alpha: float | None = 0.25  # None = no activation after layer


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A ternary FFN: layer dims d0 → d1 → … → dL."""

    name: str
    batch: int
    layers: Tuple[LayerSpec, ...]

    @property
    def d_in(self):
        return self.layers[0].k

    @property
    def d_out(self):
        return self.layers[-1].n


def ffn_spec(name, batch, dims, sparsity, seed, alpha=0.25):
    """Convenience builder: dims = [d_in, h1, ..., d_out]."""
    layers = []
    nlayers = len(dims) - 1
    for li in range(nlayers):
        layers.append(
            LayerSpec(
                k=dims[li],
                n=dims[li + 1],
                sparsity=sparsity,
                seed=seed + li,
                # PReLU between layers, none after the output layer.
                prelu_alpha=alpha if li + 1 < nlayers else None,
            )
        )
    return ModelSpec(name=name, batch=batch, layers=tuple(layers))


def generate_ternary(k, n, sparsity, seed):
    """Exact-sparsity balanced ternary weights, deterministic by seed.

    Mirrors the distribution of Rust's ``TernaryMatrix::random`` (uniform
    placement, signs split as evenly as possible). The exact permutation
    differs (different PRNG); cross-backend equivalence tests therefore
    exchange the *actual* weight bytes through the artifact manifest
    rather than regenerating them.
    """
    rng = np.random.default_rng(seed)
    total = k * n
    nnz = int(round(sparsity * total))
    w = np.zeros(total, dtype=np.int8)
    idx = rng.choice(total, size=nnz, replace=False)
    signs = np.ones(nnz, dtype=np.int8)
    signs[: nnz // 2] = -1
    rng.shuffle(signs)
    w[idx] = signs
    return w.reshape(k, n)


def generate_bias(n, seed):
    rng = np.random.default_rng(seed + 7777)
    return rng.uniform(-0.5, 0.5, size=n).astype(np.float32)


@dataclasses.dataclass
class ModelWeights:
    """Materialized weights for a ModelSpec."""

    spec: ModelSpec
    ws: List[np.ndarray]  # int8 (K, N)
    bs: List[np.ndarray]  # float32 (N,)

    @classmethod
    def generate(cls, spec: ModelSpec) -> "ModelWeights":
        ws, bs = [], []
        for layer in spec.layers:
            ws.append(generate_ternary(layer.k, layer.n, layer.sparsity, layer.seed))
            bs.append(generate_bias(layer.n, layer.seed))
        return cls(spec=spec, ws=ws, bs=bs)


def pick_tiles(m, k, n):
    """Choose Pallas tile sizes dividing the problem shape while keeping
    the per-step VMEM estimate under budget."""

    def largest_divisor_le(x, cap):
        d = min(x, cap)
        while x % d:
            d -= 1
        return d

    bm = largest_divisor_le(m, tk.DEFAULT_BM)
    bk = largest_divisor_le(k, tk.DEFAULT_BK)
    bn = largest_divisor_le(n, tk.DEFAULT_BN)
    # VMEM guard: shrink bk first (the paper shrinks the K working set).
    while tk.vmem_bytes_per_step(bm, bk, bn) > 8 * 2**20 and bk > 1:
        bk = largest_divisor_le(k, bk // 2)
    return bm, bk, bn


def forward(weights: ModelWeights, x):
    """Full FFN forward through the Pallas sign-split kernel."""
    h = x
    for layer, w, b in zip(weights.spec.layers, weights.ws, weights.bs):
        bm, bk, bn = pick_tiles(h.shape[0], layer.k, layer.n)
        h = tk.ternary_gemm(
            h, jnp.asarray(w), jnp.asarray(b), bm=bm, bk=bk, bn=bn
        )
        if layer.scale != 1.0:
            h = h * layer.scale
        if layer.prelu_alpha is not None:
            h = tk.prelu(h, layer.prelu_alpha)
    return h


def forward_ref(weights: ModelWeights, x):
    """Pure-jnp oracle forward (no Pallas) for pytest comparison."""
    from compile.kernels import ref

    h = x
    for layer, w, b in zip(weights.spec.layers, weights.ws, weights.bs):
        h = ref.ternary_gemm_ref(h, jnp.asarray(w), jnp.asarray(b))
        if layer.scale != 1.0:
            h = h * layer.scale
        if layer.prelu_alpha is not None:
            h = ref.prelu_ref(h, layer.prelu_alpha)
    return h


def lower_to_hlo_text(weights: ModelWeights) -> str:
    """AOT-lower the model (weights constant-folded) to HLO text.

    HLO *text* is the interchange format: jax ≥ 0.5 emits HloModuleProto
    with 64-bit instruction ids that xla_extension 0.5.1 (the version the
    Rust ``xla`` crate links) rejects; the text parser reassigns ids.
    """
    from jax._src.lib import xla_client as xc

    spec = weights.spec

    def fn(x):
        return (forward(weights, x),)

    x_spec = jax.ShapeDtypeStruct((spec.batch, spec.d_in), jnp.float32)
    lowered = jax.jit(fn).lower(x_spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides big literals as `constant({...})`, which xla_extension
    # 0.5.1's text parser silently zero-fills — the model weights are
    # constant-folded into this module and must survive the round-trip.
    return comp.as_hlo_text(print_large_constants=True)
