"""Layer-1 Pallas kernels: sparse ternary GEMM rethought for TPU.

HARDWARE ADAPTATION (see DESIGN.md §Hardware-Adaptation). The paper's CPU
kernels chase cache locality of gathered X reads; a TPU has no caches to
manage — it has an explicit HBM↔VMEM schedule. The paper's two core ideas
map as follows:

* **Sign separation (TCSC)** → split ternary W into two *binary* masks
  P = (W > 0), N = (W < 0) and compute ``Y = X·P − X·N + b``. No ±1
  multiplies survive (the masks are 0/1 and the MXU contraction of a
  binary operand is add-only dataflow), which is the paper's
  "additions and subtractions only" insight expressed as MXU work.

* **Blocking (BlockedTCSC, B = 4096)** → the K dimension is tiled by the
  ``BlockSpec`` grid: each grid step stages an (bm × bk) X tile and a
  (bk × bn) W tile in VMEM and accumulates into the output tile, exactly
  the "constrain the working set to a block" trick, with VMEM playing the
  role of M1's L1.

* **Symmetric padded format** → the gather kernel below takes per-column
  index tensors padded to a *static* shape with a dummy index K that
  points at a zeroed pad column of X — shape-static gathers are the TPU
  equivalent of the paper's dummy-slot trick for NEON symmetry.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is asserted against ``ref.py`` by pytest, and
TPU-perf structure (VMEM footprint per step) is estimated in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-friendly (128 lanes) while keeping the VMEM
# working set (bm·bk + 2·bk·bn + bm·bn f32) ≪ 16 MB.
DEFAULT_BM = 8
DEFAULT_BK = 512
DEFAULT_BN = 128


def _signsplit_kernel(x_ref, wp_ref, wn_ref, b_ref, o_ref, *, nsteps_k):
    """One (m, n, k) grid step of the sign-split ternary GEMM.

    Accumulates ``x_tile @ pos_tile − x_tile @ neg_tile`` into the output
    tile; the bias is added on the first K step so the total add count
    matches the paper's cost model (1 + s·K adds per output).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(b_ref[...], o_ref.shape)

    x = x_ref[...]
    # Binary masks arrive as int8; promote to f32 inside VMEM.
    pos = wp_ref[...].astype(jnp.float32)
    neg = wn_ref[...].astype(jnp.float32)
    acc = jnp.dot(x, pos, preferred_element_type=jnp.float32) - jnp.dot(
        x, neg, preferred_element_type=jnp.float32
    )
    o_ref[...] += acc
    del nsteps_k  # shape bookkeeping only


def ternary_gemm(x, w, bias, *, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """Pallas sign-split ternary GEMM: ``Y = X·W + b``.

    Args:
      x: (M, K) float32 activations.
      w: (K, N) int8 ternary weights in {-1, 0, +1}.
      bias: (N,) float32.
      bm/bk/bn: VMEM tile sizes; shapes must not be smaller than the tile
        (callers pad or shrink — the AOT driver picks tiles per shape).

    Returns:
      (M, N) float32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"X cols {k} != W rows {k2}"
    assert bias.shape == (n,)
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"shape ({m},{k},{n}) not divisible by tiles ({bm},{bk},{bn})"
    )
    # Sign-split outside the kernel: the masks are weights, computed once
    # at trace time and constant-folded into the AOT artifact.
    w_pos = (w > 0).astype(jnp.int8)
    w_neg = (w < 0).astype(jnp.int8)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_signsplit_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_pos, w_neg, bias)


def _gather_kernel(x_ref, pos_ref, neg_ref, b_ref, o_ref):
    """One N-block of the padded-gather kernel.

    ``x_ref`` holds the full padded activation row-block (M, K+1);
    ``pos_ref``/``neg_ref`` hold (bn, P) static-shape index tiles. Dummy
    indices point at the zero pad column, contributing nothing — the
    symmetric-format trick.
    """
    x = x_ref[...]  # (m, k+1)
    pos = pos_ref[...]  # (bn, p)
    neg = neg_ref[...]
    # (m, bn, p) gathers, reduced over p. jnp.take is shape-static.
    acc = jnp.take(x, pos, axis=1).sum(axis=-1) - jnp.take(x, neg, axis=1).sum(
        axis=-1
    )
    o_ref[...] = acc + b_ref[...][None, :]


def ternary_gemm_gather(x_padded, pos_idx, neg_idx, bias, *, bn=DEFAULT_BN):
    """Pallas padded-gather ternary GEMM (symmetric-TCSC analog).

    Args:
      x_padded: (M, K+1) activations, last column all zeros.
      pos_idx: (N, P) int32 indices of +1 entries per column, padded with K.
      neg_idx: (N, P) int32 indices of -1 entries per column, padded with K.
      bias: (N,) float32.

    Returns:
      (M, N) float32.
    """
    m, kp1 = x_padded.shape
    n, p = pos_idx.shape
    assert neg_idx.shape == (n, p)
    assert bias.shape == (n,)
    bn = min(bn, n)
    assert n % bn == 0, f"N={n} not divisible by bn={bn}"
    del kp1
    grid = (n // bn,)
    return pl.pallas_call(
        _gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(x_padded.shape, lambda j: (0, 0)),
            pl.BlockSpec((bn, p), lambda j: (j, 0)),
            pl.BlockSpec((bn, p), lambda j: (j, 0)),
            pl.BlockSpec((bn,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x_padded, pos_idx, neg_idx, bias)


def _prelu_kernel(y_ref, o_ref, *, alpha):
    y = y_ref[...]
    o_ref[...] = jnp.where(y > 0, y, alpha * y)


def prelu(y, alpha):
    """Pallas PReLU (fused into the FFN at the L2 level)."""
    return pl.pallas_call(
        functools.partial(_prelu_kernel, alpha=alpha),
        out_shape=jax.ShapeDtypeStruct(y.shape, y.dtype),
        interpret=True,
    )(y)


def pack_padded_indices(w, pad_multiple=1):
    """Build the padded index tensors the gather kernel consumes.

    Returns (pos_idx, neg_idx, pad_len): (N, P) int32 arrays whose padding
    entries equal K (the dummy slot). P is the max per-column per-sign
    count, rounded up to ``pad_multiple``.

    This is the Python twin of the Rust ``SymmetricTcsc`` constructor.
    """
    import numpy as np

    w = np.asarray(w)
    k, n = w.shape
    pos_lists = [np.nonzero(w[:, j] > 0)[0] for j in range(n)]
    neg_lists = [np.nonzero(w[:, j] < 0)[0] for j in range(n)]
    p = max([1] + [len(v) for v in pos_lists + neg_lists])
    if p % pad_multiple:
        p += pad_multiple - p % pad_multiple
    pos = np.full((n, p), k, dtype=np.int32)
    neg = np.full((n, p), k, dtype=np.int32)
    for j in range(n):
        pos[j, : len(pos_lists[j])] = pos_lists[j]
        neg[j, : len(neg_lists[j])] = neg_lists[j]
    return jnp.asarray(pos), jnp.asarray(neg), p


def pad_activations(x):
    """Append the zero dummy column: (M, K) → (M, K+1)."""
    m = x.shape[0]
    return jnp.concatenate([x, jnp.zeros((m, 1), x.dtype)], axis=1)


def vmem_bytes_per_step(bm, bk, bn):
    """Estimated VMEM working set of one sign-split grid step (bytes).

    x tile (bm·bk f32) + two mask tiles (bk·bn i8 each, promoted to f32
    inside the step → count f32) + out tile (bm·bn f32) + bias (bn f32).
    Used by DESIGN.md's TPU-perf estimate and the aot driver's tile picker.
    """
    f32 = 4
    return f32 * (bm * bk + 2 * bk * bn + bm * bn + bn)
