"""Pure-jnp reference oracle for the ternary GEMM kernels.

This is the CORE correctness signal for Layer 1: every Pallas kernel in
this package must match these functions to float32 tolerance across the
hypothesis shape/dtype sweeps in ``python/tests/test_kernel.py``.
"""

import jax.numpy as jnp


def ternary_gemm_ref(x, w, bias):
    """Y = X · W + b with ternary W.

    Args:
      x: (M, K) float activations.
      w: (K, N) int8 ternary weights in {-1, 0, +1}.
      bias: (N,) float bias, broadcast-added to each row.

    Returns:
      (M, N) float32 output.
    """
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + bias.astype(
        jnp.float32
    )


def ternary_gemm_signsplit_ref(x, w, bias):
    """Sign-split formulation: Y = X·P − X·N + b with binary P/N masks.

    Numerically identical to :func:`ternary_gemm_ref`; written the way the
    Pallas kernel computes it (the paper's TCSC sign separation mapped to
    TPU: two binary matmuls instead of one ternary one — no ±1 multiplies).
    """
    xf = x.astype(jnp.float32)
    pos = (w > 0).astype(jnp.float32)
    neg = (w < 0).astype(jnp.float32)
    return xf @ pos - xf @ neg + bias.astype(jnp.float32)


def prelu_ref(y, alpha):
    """PReLU: y if y > 0 else alpha * y."""
    return jnp.where(y > 0, y, alpha * y)


def padded_gather_ref(x_padded, pos_idx, neg_idx, bias):
    """Oracle for the padded-gather (symmetric TCSC analog) kernel.

    Args:
      x_padded: (M, K+1) activations whose last column is all zeros — the
        dummy slot that padded indices point at.
      pos_idx: (N, P) int32 row indices of +1 entries, padded with K.
      neg_idx: (N, P) int32 row indices of -1 entries, padded with K.
      bias: (N,) float bias.

    Returns:
      (M, N) float32 output.
    """
    # (M, N, P) gathers — fine as an oracle, the kernel does it blockwise.
    pos = jnp.take(x_padded, pos_idx, axis=1)  # (M, N, P)
    neg = jnp.take(x_padded, neg_idx, axis=1)
    return pos.sum(axis=-1) - neg.sum(axis=-1) + bias.astype(jnp.float32)


def ffn_ref(x, w1, b1, w2, b2, alpha):
    """Two-layer ternary FFN: PReLU between the ternary GEMMs."""
    h = prelu_ref(ternary_gemm_ref(x, w1, b1), alpha)
    return ternary_gemm_ref(h, w2, b2)
