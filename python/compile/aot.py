"""AOT driver: lower the ternary-FFN model variants to HLO text artifacts.

Run once at build time (``make artifacts``); Python never appears on the
serving path. For every model variant this emits:

  artifacts/<name>.hlo.txt     — HLO text the Rust PJRT runtime compiles
  artifacts/<name>.w<i>.i8     — raw int8 ternary weights (K·N, row-major)
  artifacts/<name>.b<i>.f32    — raw little-endian f32 bias (N)
  artifacts/manifest.json      — shapes, seeds, tile choices, file index

The weight byte dumps let the Rust coordinator build its *native* kernels
over the identical model, enabling the cross-backend equivalence check
(`stgemm selftest`, rust/tests/runtime_hlo.rs).
"""

import argparse
import json
import os
import sys

import numpy as np

from compile import model as M


def default_variants():
    """Model variants compiled into artifacts.

    e2e: the end-to-end serving FFN (d 256→1024→256). The tiny variant
    keeps runtime tests fast; batch sizes cover the dynamic batcher's
    padding buckets.
    """
    out = []
    for batch in (1, 8):
        out.append(
            M.ffn_spec(
                name=f"ffn_tiny_b{batch}",
                batch=batch,
                dims=[64, 128, 64],
                sparsity=0.25,
                seed=1234,
            )
        )
        out.append(
            M.ffn_spec(
                name=f"ffn_e2e_b{batch}",
                batch=batch,
                dims=[256, 1024, 256],
                sparsity=0.25,
                seed=4321,
            )
        )
    return out


def emit_variant(weights: M.ModelWeights, outdir: str) -> dict:
    spec = weights.spec
    hlo = M.lower_to_hlo_text(weights)
    hlo_path = os.path.join(outdir, f"{spec.name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)
    layer_entries = []
    for i, (layer, w, b) in enumerate(zip(spec.layers, weights.ws, weights.bs)):
        w_file = f"{spec.name}.w{i}.i8"
        b_file = f"{spec.name}.b{i}.f32"
        w.astype(np.int8).tofile(os.path.join(outdir, w_file))
        b.astype("<f4").tofile(os.path.join(outdir, b_file))
        layer_entries.append(
            {
                "k": layer.k,
                "n": layer.n,
                "sparsity": layer.sparsity,
                "seed": layer.seed,
                "prelu_alpha": layer.prelu_alpha,
                "weights_file": w_file,
                "bias_file": b_file,
                "nnz": int(np.count_nonzero(w)),
            }
        )
    # A probe vector for smoke checks: deterministic input + model output.
    rng = np.random.default_rng(99)
    probe_x = rng.uniform(-1, 1, size=(spec.batch, spec.d_in)).astype(np.float32)
    probe_y = np.asarray(M.forward_ref(weights, probe_x))
    probe_x_file = f"{spec.name}.probe_x.f32"
    probe_y_file = f"{spec.name}.probe_y.f32"
    probe_x.astype("<f4").tofile(os.path.join(outdir, probe_x_file))
    probe_y.astype("<f4").tofile(os.path.join(outdir, probe_y_file))
    return {
        "name": spec.name,
        "batch": spec.batch,
        "d_in": spec.d_in,
        "d_out": spec.d_out,
        "hlo_file": f"{spec.name}.hlo.txt",
        "layers": layer_entries,
        "probe_x_file": probe_x_file,
        "probe_y_file": probe_y_file,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated variant names to build"
    )
    args = ap.parse_args(argv)
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    variants = default_variants()
    if args.only:
        keep = set(args.only.split(","))
        variants = [v for v in variants if v.name in keep]
        if not variants:
            print(f"no variant matches {args.only}", file=sys.stderr)
            return 2
    manifest = {"version": 1, "models": []}
    for spec in variants:
        print(f"[aot] lowering {spec.name} (batch={spec.batch}, "
              f"dims={[spec.d_in] + [l.n for l in spec.layers]}) ...")
        weights = M.ModelWeights.generate(spec)
        manifest["models"].append(emit_variant(weights, outdir))
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {len(manifest['models'])} variants to {outdir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
