//! END-TO-END DRIVER (EXPERIMENTS.md E11): the full three-layer system on
//! a real serving workload.
//!
//! 1. Loads the JAX/Pallas AOT artifact (`make artifacts`) — weights,
//!    probes and HLO produced at build time by Python.
//! 2. Builds the *identical* model for the native Rust kernel path from
//!    the artifact's weight dumps.
//! 3. Cross-checks native vs PJRT/XLA outputs (layer-stack equivalence).
//! 4. Starts the HTTP server with dynamic batching and drives it with
//!    concurrent clients, reporting latency/throughput for both backends.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_server
//! ```

use std::sync::Arc;
use std::time::Duration;

use stgemm::coordinator::server::{Server, ServerConfig};
use stgemm::coordinator::{Backend, BatchPolicy, Engine, LoadGenerator, Router};
use stgemm::model::{TernaryLinear, TernaryMlp};
use stgemm::plan::{PlanHints, Planner};
use stgemm::runtime::{Manifest, XlaExecutor};
use stgemm::tensor::Matrix;

fn build_native(manifest: &Manifest, base: &str, planner: &Planner) -> TernaryMlp {
    // Kernel choice is the planner's job (tuning table + paper
    // heuristics); serving code no longer names kernels.
    let hints = PlanHints {
        expected_batch: 8,
        ..Default::default()
    };
    let v0 = manifest.variants_of(base)[0];
    let mut layers = Vec::new();
    for (i, l) in v0.layers.iter().enumerate() {
        let w = v0.load_weights(&manifest.dir, i).expect("weights");
        let b = v0.load_bias(&manifest.dir, i).expect("bias");
        layers.push(
            TernaryLinear::planned(planner, &w, b, 1.0, l.prelu_alpha, &hints)
                .expect("layer"),
        );
    }
    TernaryMlp::from_layers(base.to_string(), layers).expect("mlp")
}

fn main() {
    let base = "ffn_e2e";
    println!("=== stgemm end-to-end driver: {base} (256→1024→256 ternary FFN) ===\n");

    // --- 1. Artifacts (fail with instructions if missing) -----------------
    let manifest = Manifest::load("artifacts").unwrap_or_else(|e| {
        eprintln!("error: {e}\nrun `make artifacts` first");
        std::process::exit(1);
    });

    // --- 2+3. Native model from artifact weights + cross-check ------------
    let planner = Planner::new();
    let native = build_native(&manifest, base, &planner);
    let xla = XlaExecutor::spawn(&manifest, base).expect("spawn XLA service");
    println!(
        "[1] artifact loaded: buckets {:?}, d_in={}, d_out={}",
        xla.buckets(),
        xla.d_in,
        xla.d_out
    );
    let engine_check = Engine::new(base, native).with_xla(xla);
    let x = Matrix::random(8, engine_check.d_in(), 2026);
    let (_n, _x2, diff) = engine_check.cross_check(&x).expect("cross-check");
    println!("[2] native vs PJRT/XLA cross-check: maxΔ = {diff:.2e} (tolerance 1e-3)");
    assert!(diff < 1e-3, "backends disagree!");

    // Probe verification against the Python-computed outputs.
    for v in manifest.variants_of(base) {
        let px = Matrix::from_slice(v.batch, v.d_in, &v.load_probe_x(&manifest.dir).unwrap());
        let py = Matrix::from_slice(v.batch, v.d_out, &v.load_probe_y(&manifest.dir).unwrap());
        let y = engine_check.infer_matrix(&px).unwrap();
        assert!(
            y.allclose(&py, 1e-3),
            "{}: probe mismatch {}",
            v.name,
            y.max_abs_diff(&py)
        );
        println!("[3] probe {}: OK", v.name);
    }

    // --- 4. Serve over HTTP with both backends, measure -------------------
    let (clients, reqs) = (8usize, 100usize);
    for backend in [Backend::Native, Backend::Xla] {
        let native = build_native(&manifest, base, &planner);
        let xla = XlaExecutor::spawn(&manifest, base).expect("xla");
        let engine = Engine::new(base, native).with_xla(xla).with_backend(backend);
        let d_in = engine.d_in();
        let mut router = Router::new();
        router.register(
            engine,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
            },
        );
        let router = Arc::new(router);
        let server = Server::start(Arc::clone(&router), ServerConfig::default())
            .expect("start server");
        println!("\n[4] serving on http://{} backend={backend:?}", server.local_addr);
        let gen = LoadGenerator {
            clients,
            requests_per_client: reqs,
            d_in,
            model: base.to_string(),
            seed: 99,
            request_timeout: Duration::from_secs(30),
        };
        let report = gen.run_http(server.local_addr);
        println!("    {}", report.summary());
        let engine = router.engine(base).unwrap();
        println!(
            "    server-side: mean batch {:.2}, compute mean {:.0} µs",
            engine.metrics.mean_batch_size(),
            engine.metrics.compute_latency.mean_us()
        );
        assert_eq!(report.errors, 0, "no request may fail");
    }

    println!("\n=== end-to-end driver PASSED: all layers compose ===");
}
