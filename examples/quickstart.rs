//! Quickstart: build a ternary matrix, run every registry kernel through
//! the planning layer, verify against the dense oracle, print a small
//! performance table, and show what the planner would pick on its own.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use stgemm::bench::report::Table;
use stgemm::kernels::{dense_oracle, kernel_names, KernelParams};
use stgemm::perf::flops::CostModel;
use stgemm::perf::timer::CycleTimer;
use stgemm::plan::{Epilogue, PlanHints, Planner};
use stgemm::tensor::Matrix;
use stgemm::ternary::TernaryMatrix;

fn main() {
    // The paper's problem: Y = X·W + b with ternary W.
    let (m, k, n, sparsity) = (8, 2048, 512, 0.25f32);
    println!("Sparse Ternary GEMM quickstart: M={m} K={k} N={n} s={sparsity}");

    let w = TernaryMatrix::random(k, n, sparsity, 42);
    let x = Matrix::random(m, k, 1);
    let bias: Vec<f32> = (0..n).map(|i| (i % 5) as f32 * 0.1).collect();
    let oracle = dense_oracle(&x, &w, &bias);
    println!(
        "W: {}×{} ternary, nnz={} ({:.1}%)\n",
        k,
        n,
        w.nnz(),
        100.0 * w.density()
    );

    let flops = CostModel::new(m, k, n, sparsity).flops();
    let timer = CycleTimer::new(1, 3);
    let planner = Planner::new();
    let mut table = Table::new(
        "kernel comparison (all must match the oracle)",
        &["kernel", "correct", "flops/cycle", "GFLOP/s"],
    );
    for &name in kernel_names() {
        // Pin each kernel explicitly; serving code would omit the hint and
        // let the planner choose.
        let plan = planner
            .plan(
                &w,
                KernelParams::default(),
                Epilogue::with_bias(bias.clone()),
                &PlanHints::with_kernel(name.parse().unwrap()),
            )
            .unwrap();
        let mut y = Matrix::zeros(m, n);
        plan.run(&x, &mut y).unwrap();
        let correct = y.allclose(&oracle, 1e-3);
        let meas = timer.run(|| plan.run(&x, &mut y).expect("plan run"));
        table.row(vec![
            name.to_string(),
            if correct { "✓".into() } else { "✗ FAIL".into() },
            format!("{:.3}", meas.flops_per_cycle(flops)),
            format!("{:.2}", meas.gflops_per_second(flops)),
        ]);
        assert!(correct, "kernel {name} diverged from the oracle");
    }
    println!("{}", table.render());
    println!("All kernels verified against the dense oracle.");

    let auto = planner
        .plan(
            &w,
            KernelParams::default(),
            Epilogue::with_bias(bias.clone()),
            &PlanHints::default(),
        )
        .unwrap();
    println!(
        "planner pick for (K={k}, s={sparsity}) with no hint: {}",
        auto.kernel_name()
    );
}
