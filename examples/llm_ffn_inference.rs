//! Domain example: a BitNet-style quantized LLM FFN block.
//!
//! Takes float weights (a stand-in for a trained checkpoint), quantizes
//! them to ternary with the absmean quantizer, builds the sparse serving
//! model, and compares quantized inference against the float reference —
//! the paper's motivating workload end to end.
//!
//! ```bash
//! cargo run --release --example llm_ffn_inference
//! ```

use stgemm::kernels::prelu_inplace;
use stgemm::model::{TernaryLinear, TernaryMlp};
use stgemm::perf::timer::CycleTimer;
use stgemm::plan::{PlanHints, Planner};
use stgemm::tensor::Matrix;
use stgemm::ternary::quantize_absmean;

/// Float FFN reference: h = PReLU(x·W1 + b1); y = h·W2 + b2.
fn float_ffn(x: &Matrix, w1: &Matrix, b1: &[f32], w2: &Matrix, b2: &[f32]) -> Matrix {
    let mm = |a: &Matrix, w: &Matrix, b: &[f32]| {
        let mut y = Matrix::zeros(a.rows(), w.cols());
        for r in 0..a.rows() {
            for c in 0..w.cols() {
                let mut acc = b[c];
                for i in 0..w.rows() {
                    acc += a[(r, i)] * w[(i, c)];
                }
                y[(r, c)] = acc;
            }
        }
        y
    };
    let mut h = mm(x, w1, b1);
    prelu_inplace(&mut h, 0.25);
    mm(&h, w2, b2)
}

fn main() {
    // "Checkpoint": d_model=256, d_ff=1024 float FFN weights.
    let (d_model, d_ff, batch) = (256usize, 1024usize, 8usize);
    println!("BitNet-style FFN: d_model={d_model}, d_ff={d_ff}, batch={batch}\n");
    let w1f = Matrix::random(d_model, d_ff, 7);
    let w2f = Matrix::random(d_ff, d_model, 8);
    let b1: Vec<f32> = vec![0.01; d_ff];
    let b2: Vec<f32> = vec![-0.01; d_model];

    // Quantize: absmean → ternary + per-tensor scale.
    let q1 = quantize_absmean(&w1f);
    let q2 = quantize_absmean(&w2f);
    println!(
        "layer 1: scale={:.4}, density={:.1}%, quant MSE={:.5}",
        q1.scale,
        100.0 * q1.weights.density(),
        q1.mse(&w1f)
    );
    println!(
        "layer 2: scale={:.4}, density={:.1}%, quant MSE={:.5}\n",
        q2.scale,
        100.0 * q2.weights.density(),
        q2.mse(&w2f)
    );

    // Serving model: the planner picks each layer's kernel from its
    // (K, sparsity) class — no kernel names in model-building code.
    let planner = Planner::new();
    let hints = PlanHints {
        expected_batch: batch,
        ..Default::default()
    };
    let l1 = TernaryLinear::planned(
        &planner,
        &q1.weights,
        b1.clone(),
        q1.scale,
        Some(0.25),
        &hints,
    )
    .unwrap();
    let l2 =
        TernaryLinear::planned(&planner, &q2.weights, b2.clone(), q2.scale, None, &hints)
            .unwrap();
    println!(
        "planner picks: layer 1 → {}, layer 2 → {}\n",
        l1.kernel_name(),
        l2.kernel_name()
    );
    let model = TernaryMlp::from_layers("bitnet_ffn".into(), vec![l1, l2]).unwrap();

    // Compare against the float reference on a batch of activations.
    let x = Matrix::random(batch, d_model, 9);
    let y_float = float_ffn(&x, &w1f, &b1, &w2f, &b2);
    let y_ternary = model.forward(&x).expect("forward");

    // Quantization error in the *output* (relative RMS).
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in y_float.as_slice().iter().zip(y_ternary.as_slice()) {
        num += ((a - b) as f64).powi(2);
        den += (*a as f64).powi(2);
    }
    let rel_rms = (num / den.max(1e-12)).sqrt();
    println!("output relative RMS error (quantization cost): {rel_rms:.4}");

    // Throughput of the quantized path.
    let timer = CycleTimer::new(1, 5);
    let meas = timer.run(|| {
        std::hint::black_box(model.forward(&x).expect("forward"));
    });
    let flops = model.flops(batch);
    println!(
        "quantized FFN forward: {:.2} GFLOP/s ({:.3} flops/cycle), {:.1} µs/batch",
        meas.gflops_per_second(flops),
        meas.flops_per_cycle(flops),
        meas.seconds * 1e6
    );
    assert!(
        rel_rms < 1.0,
        "ternary output should stay in the same order of magnitude"
    );
    println!("\nOK — quantized serving path verified against the float reference.");
}
