//! Domain example: how kernel choice interacts with sparsity — a compact
//! reproduction of the paper's Fig 9 story plus the rejected formats'
//! crossover behaviour, on shapes that finish in seconds.
//!
//! ```bash
//! cargo run --release --example sparsity_sweep
//! ```

use stgemm::bench::report::Table;
use stgemm::kernels::KernelParams;
use stgemm::bench::harness::measure_kernel;
use stgemm::perf::timer::CycleTimer;

fn main() {
    let (m, k, n) = (16usize, 4096usize, 256usize);
    let timer = CycleTimer::new(1, 3);
    println!("Sparsity sweep: M={m}, K={k}, N={n} (paper sparsities)\n");

    let kernels = [
        "base_tcsc",
        "unrolled_tcsc_12",
        "interleaved_blocked_tcsc",
        "compressed_ternary",
        "inverted_index",
    ];
    let mut headers = vec!["kernel".to_string()];
    headers.extend(
        stgemm::PAPER_SPARSITIES
            .iter()
            .map(|s| format!("s={s} f/c")),
    );
    let mut table = Table::new(
        "flops/cycle by sparsity",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for kernel in kernels {
        let mut row = vec![kernel.to_string()];
        for &s in &stgemm::PAPER_SPARSITIES {
            let meas = measure_kernel(kernel, m, k, n, s, 11, KernelParams::default(), &timer);
            row.push(format!("{:.3}", meas.flops_per_cycle()));
        }
        table.row(row);
    }
    println!("{}", table.render());
    println!(
        "Expected shapes (paper §3/§4): the blocked+interleaved kernel leads and\n\
         stays stable across sparsity; value compression only competes at s=50%\n\
         (wasted work on packed zeros below); the inverted index trails base\n\
         (sign-decode branch in the inner loop)."
    );
}
